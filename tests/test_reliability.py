"""Fault plane + integrity + breaker unit tests (DESIGN.md §14).

The chaos *lane* (-m chaos) splits into two files: this one proves each
reliability mechanism in isolation — the deterministic fault plane, the
circuit-breaker state machine, SHA-256 snapshot/segment/checkpoint
integrity with quarantine-and-fall-back — while ``test_chaos.py`` composes
them into the fleet-under-fire acceptance scenario. Everything runs on
injectable clocks/sleeps so no test spends real wall time on a schedule.
"""
import os
import textwrap
import threading

import numpy as np
import pytest

from repro.analysis import concurrency as cc
from repro.analysis import report
from repro.checkpoint import io, snapshots
from repro.checkpoint.manager import CheckpointManager
from repro.reliability import faults
from repro.reliability.faults import FaultInjected, FaultPlane
from repro.serving.health import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker)
from repro.serving.watcher import SnapshotWatcher

pytestmark = pytest.mark.chaos

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


class _EngineStub:
    """Just enough engine for a SnapshotWatcher: records swaps."""

    def __init__(self):
        self.model_version = None
        self.swaps = []

    def swap_model(self, model, version=None):
        self.model_version = version
        self.swaps.append(version)


def _model(seed=0, K=6, V=40):
    import jax.numpy as jnp

    from repro.core import rtlda

    rng = np.random.default_rng(seed)
    phi = jnp.asarray(rng.integers(0, 20, (V, K)).astype(np.int32))
    return rtlda.build_model(phi, jnp.float32(0.01),
                             jnp.full((K,), 0.5, jnp.float32))


def _corrupt(path):
    """Flip a few payload bytes in place (torn write / bit rot)."""
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        block = f.read(8)
        f.seek(-len(block), os.SEEK_CUR)
        f.write(bytes(b ^ 0xFF for b in block))


# ------------------------------------------------------------- fault plane --


def test_fault_plane_fail_nth_and_after():
    plane = FaultPlane(seed=0)
    plane.fail("engine.infer", nth=3)
    outcomes = []
    for _ in range(5):
        try:
            plane.hit("engine.infer")
            outcomes.append(True)
        except FaultInjected as exc:
            outcomes.append(False)
            assert exc.seam == "engine.infer" and exc.hit_index == 3
    assert outcomes == [True, True, False, True, True]
    assert plane.hits("engine.infer") == 5
    assert plane.injected("engine.infer") == 1

    plane2 = FaultPlane()
    plane2.fail("disk.segment_read", key="2", after=3)
    for i in range(1, 7):
        try:
            plane2.hit("disk.segment_read", key="2")
            assert i < 3
        except FaultInjected:
            assert i >= 3
    # a different key never matches the keyed rule
    plane2.hit("disk.segment_read", key="0")
    assert plane2.injected("disk.segment_read", key="0") == 0


def test_fault_plane_unconditional_arm_fires_every_hit():
    plane = FaultPlane().fail("watcher.poll")
    for _ in range(3):
        with pytest.raises(FaultInjected):
            plane.hit("watcher.poll")
    assert plane.injected("watcher.poll") == 3


def test_fault_plane_unknown_seam_is_a_programming_error():
    plane = FaultPlane()
    with pytest.raises(ValueError):
        plane.fail("engine.inferr")
    with pytest.raises(ValueError):
        plane.hit("no.such.seam")


def test_fault_plane_rate_is_deterministic_by_seed():
    def pattern(seed):
        plane = FaultPlane(seed=seed)
        plane.fail("snapshot.load", rate=0.3)
        out = []
        for _ in range(200):
            try:
                plane.hit("snapshot.load", key="7")
                out.append(0)
            except FaultInjected:
                out.append(1)
        return out

    a, b = pattern(11), pattern(11)
    assert a == b, "same seed must make identical per-hit decisions"
    assert pattern(12) != a, "different seed must decorrelate"
    assert 30 <= sum(a) <= 90        # loose band around rate·N = 60


def test_fault_plane_slow_uses_injectable_sleep():
    sleeps = []
    plane = FaultPlane(sleep=sleeps.append)
    plane.slow("replica.slow", 250.0, nth=2)
    plane.hit("replica.slow")
    plane.hit("replica.slow")        # nth=2: sleeps, does not raise
    plane.hit("replica.slow")
    assert sleeps == [0.25]
    assert plane.injected("replica.slow") == 1


def test_fault_plane_wedge_is_deadline_bounded():
    clock = FakeClock()
    plane = FaultPlane(clock=clock,
                       sleep=lambda s: clock.advance_ms(s * 1e3))
    plane.wedge("replica.wedge", timeout_s=2.0)
    t0 = clock()
    with pytest.raises(FaultInjected):
        plane.hit("replica.wedge")
    assert clock() - t0 >= 2.0       # blocked the full (fake) deadline


def test_fault_plane_wedge_release_unblocks():
    plane = FaultPlane()
    plane.wedge("replica.wedge", timeout_s=30.0)
    raised = threading.Event()

    def _worker():
        try:
            plane.hit("replica.wedge")
        except FaultInjected:
            raised.set()

    t = threading.Thread(target=_worker, daemon=True)
    t.start()
    plane.release()
    t.join(timeout=5)
    assert raised.is_set(), "released wedge must raise, not hang"


def test_injected_context_manager_installs_and_always_uninstalls():
    assert faults.get_plane() is None
    faults.hit("engine.infer")       # disabled: a no-op, never raises
    plane = FaultPlane().fail("engine.infer")
    with pytest.raises(FaultInjected):
        with faults.injected(plane):
            assert faults.get_plane() is plane
            faults.hit("engine.infer")
    assert faults.get_plane() is None, "uninstalled even on raise"
    faults.hit("engine.infer")       # back to a no-op


# -------------------------------------------------------- circuit breaker --


def _breaker(clock, **kw):
    kw.setdefault("failure_threshold", 3)
    kw.setdefault("backoff_ms", 200.0)
    kw.setdefault("probe_timeout_ms", 1000.0)
    return CircuitBreaker(clock=clock, **kw)


def test_breaker_trips_on_consecutive_failures_only():
    clock = FakeClock()
    b = _breaker(clock)
    b.record_failure()
    b.record_failure()
    b.record_success()               # resets the consecutive counter
    b.record_failure()
    b.record_failure()
    assert b.state() == CLOSED and b.allow()
    b.record_failure()               # third consecutive: trip
    assert b.state() == OPEN and not b.allow()
    assert b.snapshot()["trips"] == 1


def test_breaker_backoff_is_deterministic_and_jittered_by_seed():
    def reopen(seed):
        clock = FakeClock()
        b = _breaker(clock, seed=seed)
        for _ in range(3):
            b.record_failure()
        return b.snapshot()["reopen_at"]

    assert reopen(5) == reopen(5)
    assert reopen(5) != reopen(6), "jitter must decorrelate by seed"
    # jitter in [0, 20%) on top of the 200 ms base rung
    assert 0.200 <= reopen(5) < 0.240


def test_breaker_half_open_admits_exactly_one_probe():
    clock = FakeClock()
    b = _breaker(clock)
    for _ in range(3):
        b.record_failure()
    assert not b.allow()
    clock.advance_ms(300.0)          # past the first-rung backoff (≤240 ms)
    assert b.state() == HALF_OPEN
    assert b.allow()                 # the one probe
    assert not b.allow()             # second concurrent request: blocked
    clock.advance_ms(1000.0)         # probe outcome never arrived: timeout
    assert b.allow(), "timed-out probe must re-admit another"
    assert b.snapshot()["probes"] == 2


def test_breaker_probe_outcome_walks_the_ladder():
    clock = FakeClock()
    b = _breaker(clock, jitter=0.0)
    for _ in range(3):
        b.record_failure()
    d1 = b.snapshot()["reopen_at"] - clock()
    clock.advance_ms(d1 * 1e3 + 1.0)
    assert b.allow()
    b.record_failure()               # probe failed: next rung
    d2 = b.snapshot()["reopen_at"] - clock()
    assert d2 == pytest.approx(2 * d1), "backoff must double per trip"
    clock.advance_ms(d2 * 1e3 + 1.0)
    assert b.allow()
    b.record_success()               # probe succeeded: close + reset ladder
    snap = b.snapshot()
    assert snap["state"] == CLOSED and snap["trips"] == 0
    for _ in range(3):
        b.record_failure()
    d3 = b.snapshot()["reopen_at"] - clock()
    assert d3 == pytest.approx(d1), "a recovery must reset the rung"


def test_breaker_classifies_blowouts_not_ordinary_misses():
    clock = FakeClock()
    b = _breaker(clock, failure_threshold=1, blowout_factor=3.0)
    b.record_response(120.0, 50.0)   # a miss, but under 3×: congestion
    assert b.state() == CLOSED
    b.record_response(400.0, None)   # no deadline: never a blowout
    assert b.state() == CLOSED
    b.record_response(151.0, 50.0)   # > 3×50: the replica is sick
    assert b.state() == OPEN


# ------------------------------------------------- snapshot/ckpt integrity --


def test_io_records_and_verifies_payload_sha256(tmp_path):
    path = str(tmp_path / "ckpt")
    tree = {"a": np.arange(12, dtype=np.int32)}
    io.save(path, tree, {"step": 1})
    import json
    with open(os.path.join(path, io.MANIFEST)) as f:
        manifest = json.load(f)
    assert io.PAYLOAD in manifest["sha256"]
    io.verify(path)                  # clean: no raise
    loaded, meta = io.load(path, {"a": 0})
    np.testing.assert_array_equal(loaded["a"], tree["a"])
    _corrupt(os.path.join(path, io.PAYLOAD))
    with pytest.raises(io.IntegrityError) as ei:
        io.load(path, {"a": 0})
    assert ei.value.path.endswith(io.PAYLOAD)


def test_corrupt_snapshot_raises_typed_and_quarantine_hides_it(tmp_path):
    d = str(tmp_path)
    snapshots.save_snapshot(d, 3, _model(), {"epoch": 1})
    _corrupt(os.path.join(snapshots.snapshot_path(d, 3), io.PAYLOAD))
    with pytest.raises(io.IntegrityError) as ei:
        snapshots.load_snapshot(d, 3)
    assert ei.value.version == 3     # attributed to the snapshot version
    dst = snapshots.quarantine_snapshot(d, 3)
    assert dst is not None and dst.endswith(".corrupt")
    assert os.path.isdir(dst), "bytes stay on disk for forensics"
    assert snapshots.snapshot_versions(d) == []   # invisible to readers
    assert snapshots.quarantine_snapshot(d, 3) is None  # idempotent / raced


def test_delta_chain_attributes_corruption_to_the_bad_link(tmp_path):
    import jax.numpy as jnp

    from repro.core import rtlda

    d = str(tmp_path)
    m0 = _model(seed=0)
    snapshots.save_snapshot(d, 0, m0)
    pvk1 = np.array(m0.pvk)
    pvk1[[1, 4]] += 1
    m1 = rtlda.RTLDAModel(pvk=jnp.asarray(pvk1), alpha=m0.alpha,
                          r_topic=m0.r_topic, r_value=m0.r_value)
    snapshots.save_delta_snapshot(d, 1, m1, 0, m0.pvk)
    # corrupt the BASE: loading the delta must blame v0, not v1 — the
    # watcher then quarantines the truly-bad version, not the delta on top
    _corrupt(os.path.join(snapshots.snapshot_path(d, 0), io.PAYLOAD))
    with pytest.raises(io.IntegrityError) as ei:
        snapshots.load_snapshot(d, 1)
    assert ei.value.version == 0


def test_watcher_quarantines_corrupt_publish_and_falls_back(tmp_path):
    d = str(tmp_path)
    snapshots.save_snapshot(d, 0, _model(seed=0))
    snapshots.save_snapshot(d, 1, _model(seed=1))
    _corrupt(os.path.join(snapshots.snapshot_path(d, 1), io.PAYLOAD))
    eng = _EngineStub()
    w = SnapshotWatcher(d, eng, poll_s=0.01)
    # newest-first: v1 is corrupt → quarantined; the walk falls back to v0
    # IN THE SAME TICK — one bad publish costs staleness, not availability
    assert w.poll() == 0
    assert eng.model_version == 0
    assert w.quarantined == 1
    assert snapshots.snapshot_versions(d) == [0]
    assert os.path.isdir(snapshots.snapshot_path(d, 1) + ".corrupt")
    # the next good publish converges normally
    snapshots.save_snapshot(d, 2, _model(seed=2))
    assert w.poll() == 2 and eng.model_version == 2
    assert w.poll_failures == 0 and w.quarantined == 1


def test_watcher_transient_failures_drive_exponential_backoff(tmp_path):
    d = str(tmp_path)
    snapshots.save_snapshot(d, 0, _model())
    eng = _EngineStub()
    w = SnapshotWatcher(d, eng, poll_s=0.5, max_backoff_s=4.0)
    assert w.backoff_s() == 0.5
    plane = FaultPlane().fail("watcher.poll")
    with faults.injected(plane):
        for expect in (1.0, 2.0, 4.0, 4.0):    # doubles, then caps
            assert w.poll() is None
            assert w.backoff_s() == expect
        assert w.poll_failures == 4
        assert isinstance(w.last_error, FaultInjected)
    # the dir heals: one good poll resets the streak and the cadence
    assert w.poll() == 0
    assert w.poll_failures == 0 and w.backoff_s() == 0.5


# ----------------------------------------------------- disk segment reads --


def _segment_dir(tmp_path):
    from repro.data import InMemorySource, save_segments
    from repro.data import synthetic

    c, _ = synthetic.lda_corpus(seed=1, n_docs=60, n_topics=4,
                                vocab_size=50, doc_len_mean=7)
    src = InMemorySource(c, 2, 2, 2, 4, seed=3)
    d = str(tmp_path / "segs")
    save_segments(src, d)
    return d


def test_disk_source_verifies_segments_once_and_catches_rot(tmp_path):
    from repro.data import DiskSource

    d = _segment_dir(tmp_path)
    src = DiskSource(d)
    src.segment(0)                   # verifies on first touch
    src.segment(0)                   # memoized: no re-hash
    assert 0 in src._verified
    _corrupt(os.path.join(d, "segment_00001", "word_local.npy"))
    with pytest.raises(io.IntegrityError) as ei:
        src.segment(1)
    assert "word_local" in ei.value.path
    # corruption is permanent — never burned retries re-reading rot
    plane = FaultPlane()
    with faults.injected(plane):
        with pytest.raises(io.IntegrityError):
            src.segment(1)
        assert plane.hits("disk.segment_read", key="1") == 1
    # opting out reads the (corrupt) bytes without the check
    raw = DiskSource(d, verify=False)
    raw.segment(1)


def test_disk_source_retries_transient_errors_then_surfaces(tmp_path):
    from repro.data import DiskSource

    d = _segment_dir(tmp_path)
    src = DiskSource(d, retries=2)
    plane = FaultPlane().fail("disk.segment_read", key="0", nth=1)
    with faults.injected(plane):
        sc = src.segment(0)          # first read fails, retry succeeds
        assert sc.n_real_tokens > 0
        assert plane.hits("disk.segment_read", key="0") == 2
    plane2 = FaultPlane().fail("disk.segment_read", key="1")
    with faults.injected(plane2):
        with pytest.raises(FaultInjected):
            src.segment(1)           # persistent: surfaces after retries
        assert plane2.hits("disk.segment_read", key="1") == 3


def test_checkpoint_manager_falls_back_to_last_good(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=5)
    like = {"w": 0}
    mgr.save(1, {"w": np.full(4, 1.0)})
    mgr.save(2, {"w": np.full(4, 2.0)})
    _corrupt(os.path.join(mgr.step_dir(2), io.PAYLOAD))
    tree, meta = mgr.restore_latest(like)
    assert meta["step"] == 1, "corrupt newest must fall back, not fail"
    np.testing.assert_array_equal(tree["w"], np.full(4, 1.0))
    assert mgr.steps() == [1]        # step 2 quarantined out of the listing
    assert os.path.isdir(mgr.step_dir(2) + ".corrupt")


# ------------------------------------- §12 contract over the new modules --


HEALTH_PY = os.path.join(REPO, "src", "repro", "serving", "health.py")
FAULTS_PY = os.path.join(REPO, "src", "repro", "reliability", "faults.py")


@pytest.mark.concurrency
def test_analyzer_accepts_then_catches_mutated_health():
    with open(HEALTH_PY) as f:
        src = f.read()
    clean = [f for f in cc.analyze_source(src, "health.py")
             if f.severity == report.ERROR]
    assert clean == [], [f.message for f in clean]
    mutated = src.replace(
        "    def state(self) -> str:",
        "    def _racy(self) -> None:\n"
        "        self._failures += 1\n\n"
        "    def state(self) -> str:")
    errs = [f for f in cc.analyze_source(mutated, "health.py")
            if f.severity == report.ERROR]
    assert errs and any("_failures" in f.message for f in errs)


@pytest.mark.concurrency
def test_analyzer_accepts_then_catches_mutated_faults():
    with open(FAULTS_PY) as f:
        src = f.read()
    clean = [f for f in cc.analyze_source(src, "faults.py")
             if f.severity == report.ERROR]
    assert clean == [], [f.message for f in clean]
    mutated = src.replace(
        "    def release(self) -> None:",
        "    def _racy(self) -> None:\n"
        "        self._released = True\n\n"
        "    def release(self) -> None:")
    errs = [f for f in cc.analyze_source(mutated, "faults.py")
            if f.severity == report.ERROR]
    assert errs and any("_released" in f.message for f in errs)


@pytest.mark.concurrency
def test_repolint_thread_contract_catches_stripped_guarded_by(tmp_path):
    from repro.analysis import repolint

    srcdir = tmp_path / "src"
    srcdir.mkdir()
    bare = textwrap.dedent("""
        import threading

        class Watcher:
            def start(self):
                self._thread = threading.Thread(target=self._run)
    """)
    (srcdir / "w.py").write_text(bare)
    errs = [f for f in repolint.check_thread_conventions(str(tmp_path))
            if f.severity == "error"]
    assert errs, "a thread-creating class without _GUARDED_BY must fail"
    (srcdir / "w.py").write_text(bare.replace(
        "class Watcher:",
        "class Watcher:\n    _GUARDED_BY = {\"_thread\": \"_lock\"}"))
    errs = [f for f in repolint.check_thread_conventions(str(tmp_path))
            if f.severity == "error"]
    assert errs == [], [f.message for f in errs]

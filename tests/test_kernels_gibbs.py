"""Per-kernel validation: fused Gibbs/RT-LDA kernel vs the pure-jnp oracle.

The kernel and oracle share the counter-based RNG, so agreement is required to
be EXACT (argmax over identical floats with identical tie-breaking).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import prng
from repro.kernels.gibbs import ops

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(7)


def _inputs(T, K, psi_scale=500):
    phi = jnp.array(RNG.integers(0, 50, (T, K)).astype(np.float32))
    psi = jnp.array(RNG.integers(1, psi_scale, (T, K)).astype(np.float32))
    theta = jnp.array(RNG.integers(0, 10, (T, K)).astype(np.float32))
    alpha = jnp.array(RNG.uniform(0.01, 1.0, K).astype(np.float32))
    uid = jnp.arange(T, dtype=jnp.uint32) + 31
    return phi, psi, theta, alpha, uid


@pytest.mark.parametrize("T,K", [(8, 64), (16, 100), (256, 512), (100, 700),
                                 (257, 513), (64, 1024), (31, 1000)])
@pytest.mark.parametrize("temperature", [1.0, 0.0])
def test_kernel_matches_ref(T, K, temperature):
    phi, psi, theta, alpha, uid = _inputs(T, K)
    kw = dict(vocab_size=5000, temperature=temperature)
    a = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.01), uid,
                         jnp.uint32(42), force="ref", **kw)
    b = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.01), uid,
                         jnp.uint32(42), force="interpret", **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("block_t,block_k", [(8, 128), (64, 256), (256, 512)])
def test_kernel_block_shapes(block_t, block_k):
    from repro.kernels.gibbs.kernel import gibbs_argmax_pallas
    from repro.kernels.gibbs.ref import gibbs_argmax_ref

    T, K = 96, 384
    phi, psi, theta, alpha, uid = _inputs(T, K)
    a = gibbs_argmax_ref(phi, psi, theta, alpha, jnp.float32(0.05), uid,
                         jnp.uint32(3), 1000, 1.0)
    b = gibbs_argmax_pallas(phi, psi, theta, alpha, jnp.float32(0.05), uid,
                            jnp.uint32(3), 1000, 1.0,
                            block_t=block_t, block_k=block_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gumbel_max_is_exact_categorical():
    """Empirical law of the Gumbel-max sampler matches the true posterior."""
    T, K = 4000, 12
    weights = RNG.integers(1, 40, K).astype(np.float32)
    phi = jnp.broadcast_to(jnp.array(weights)[None, :], (T, K))
    psi = jnp.full((T, K), 400.0)
    theta = jnp.zeros((T, K))
    alpha = jnp.ones(K)
    uid = jnp.arange(T, dtype=jnp.uint32)
    z = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.1), uid,
                         jnp.uint32(9), 100, 1.0, force="ref")
    p_emp = np.bincount(np.asarray(z), minlength=K) / T
    p_true = weights + 0.1
    p_true = p_true / p_true.sum()
    assert np.abs(p_emp - p_true).max() < 0.03


def test_seed_and_uid_decorrelate():
    phi, psi, theta, alpha, uid = _inputs(64, 128)
    base = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.01), uid,
                            jnp.uint32(1), 1000, 1.0, force="ref")
    other_seed = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.01),
                                  uid, jnp.uint32(2), 1000, 1.0, force="ref")
    other_uid = ops.gibbs_argmax(phi, psi, theta, alpha, jnp.float32(0.01),
                                 uid + 1000, jnp.uint32(1), 1000, 1.0, force="ref")
    assert (np.asarray(base) != np.asarray(other_seed)).any()
    assert (np.asarray(base) != np.asarray(other_uid)).any()


@given(seed=st.integers(0, 2**32 - 1), a=st.integers(0, 2**32 - 1),
       b=st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_prng_uniform_range(seed, a, b):
    u = float(prng.uniform01(jnp.uint32(seed), jnp.uint32(a), jnp.uint32(b)))
    assert 0.0 < u < 1.0


def test_prng_avalanche():
    """Adjacent counters must produce decorrelated bits (murmur3 finalizer)."""
    n = 4096
    bits = np.asarray(prng.hash_bits(jnp.uint32(5),
                                     jnp.arange(n, dtype=jnp.uint32),
                                     jnp.uint32(0)))
    as_bits = np.unpackbits(bits.view(np.uint8))
    assert abs(as_bits.mean() - 0.5) < 0.02          # balanced
    assert len(np.unique(bits)) == n                 # no collisions at 4k

"""Small-mesh dry-run sanity: lower+compile representative cells in a
subprocess (8 fake devices, 4×2 and 2×2×2 meshes). The production 512-device
sweep is launch/dryrun.py; this guards the plumbing in CI time."""
import json


CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro._compat import cost_analysis_dict
from repro.configs import get_arch
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(4, 2)
mesh3 = make_test_mesh(2, 2, n_pod=2)
results = {}
cells = [
    ("smollm-135m", "train_4k", False),
    ("smollm-135m", "decode_32k", False),
    ("qwen2-moe-a2.7b", "prefill_32k", False),
    ("graphsage-reddit", "molecule", False),
    ("autoint", "serve_p99", False),
    ("peacock-lda", "train_segment", False),
    ("smollm-135m", "train_4k", True),
    ("peacock-lda", "train_segment", True),
]
for arch, shape, mp in cells:
    spec = get_arch(arch)
    cell = spec.cell(shape, mesh3 if mp else mesh, mp)
    compiled = cell.lower().compile()
    ca = cost_analysis_dict(compiled)
    results[f"{arch}/{shape}/{'mp' if mp else 'sp'}"] = float(ca.get("flops", 0))
print("DRYRUN_SMALL_OK", json.dumps(list(results)))
"""


def test_small_mesh_dryrun(subproc):
    out = subproc(CODE, n_devices=8, timeout=900)
    assert "DRYRUN_SMALL_OK" in out

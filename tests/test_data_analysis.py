"""Data pipeline (preprocessing, placement, sharding) + jaxpr cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import corpus as corpus_mod, synthetic
from repro.dist import analysis


# ---------------------------- preprocessing ---------------------------------

def test_preprocess_five_steps():
    docs = [np.array([0, 1, 2], np.int32),       # contains rare word 2
            np.array([0, 1], np.int32),
            np.array([0, 1], np.int32),          # duplicate → removed
            np.array([3], np.int32),             # single word → removed
            np.array([0, 0, 0, 0, 0, 1], np.int32)]
    # word 0 freq 9/16 > 0.4 → removed as too frequent; word 2,3 freq 1 → rare
    c, remap = corpus_mod.preprocess(docs, vocab_size=5, min_word_freq=2,
                                     max_word_fraction=0.4)
    assert remap[0] == -1 and remap[2] == -1 and remap[3] == -1
    assert remap[1] >= 0
    # surviving docs must have ≥2 tokens and be unique
    lengths = np.bincount(c.doc_ids, minlength=c.n_docs)
    assert (lengths >= 2).all() or c.n_docs == 0


@given(v=st.integers(4, 60), m=st.integers(1, 8), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_vocab_placement_balance(v, m, seed):
    rng = np.random.default_rng(seed)
    freq = rng.zipf(1.5, v).astype(np.int64)
    shard_of, local_of, rows = corpus_mod.vocab_placement(freq, m)
    assert shard_of.shape == (v,)
    # every word placed exactly once, locals unique per shard
    for s in range(m):
        locs = local_of[shard_of == s]
        assert len(np.unique(locs)) == len(locs)
    # weighted balance: max shard load ≤ min + max single weight
    loads = np.zeros(m, np.int64)
    np.add.at(loads, shard_of, freq + 1)
    assert loads.max() - loads.min() <= freq.max() + 1


def test_shard_corpus_roundtrip():
    corpus, _ = synthetic.lda_corpus(seed=1, n_docs=150, n_topics=6,
                                     vocab_size=90, doc_len_mean=9)
    sc = corpus_mod.shard_corpus(corpus, 4, 4, 8, seed=2)
    # every real token appears exactly once (uid is a permutation)
    uids = sc.uid[sc.word_local >= 0]
    assert len(uids) == corpus.n_tokens
    assert len(np.unique(uids)) == corpus.n_tokens
    # word_local indexes are within the shard row count
    assert sc.word_local.max() < sc.rows_per_shard
    # vocab shard of sub-block m is m: verify via placement
    for m in range(4):
        wl = sc.word_local[:, m]
        valid = wl >= 0
        # reconstruct global words of this sub-block and check shard_of == m
        uid = sc.uid[:, m][valid]
        words = corpus.word_ids[uid]
        assert (sc.shard_of_word[words] == m).all()


def test_segments_partition_docs():
    corpus, _ = synthetic.lda_corpus(seed=1, n_docs=100, n_topics=6,
                                     vocab_size=60, doc_len_mean=8)
    segs = corpus_mod.segment_corpus(corpus, 3, 2, 2, 8, seed=0)
    total = sum(sc.n_real_tokens for sc in segs)
    assert total == corpus.n_tokens
    # shared vocab placement across segments
    a, b = segs.segments[0], segs.segments[1]
    np.testing.assert_array_equal(a.shard_of_word, b.shard_of_word)


def test_pods_partition_docs():
    corpus, _ = synthetic.lda_corpus(seed=1, n_docs=100, n_topics=6,
                                     vocab_size=60, doc_len_mean=8)
    scs = corpus_mod.shard_corpus_pods(corpus, 2, 2, 2, 8, seed=0)
    assert sum(sc.n_real_tokens for sc in scs) == corpus.n_tokens
    assert scs[0].word_local.shape == scs[1].word_local.shape  # common shapes


# ----------------------------- cost analyzer --------------------------------

def test_jaxpr_cost_matmul_exact():
    f = lambda a, b: a @ b
    cost = analysis.trace_cost(
        f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32))
    assert cost.flops == 2 * 64 * 128 * 32


def test_jaxpr_cost_scan_multiplies():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    cost = analysis.trace_cost(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    assert cost.flops == 7 * 2 * 32 * 32 * 32


def test_jaxpr_cost_nested_scan_and_remat():
    def f(x):
        @jax.checkpoint
        def layer(c, _):
            def inner(h, _):
                return h @ h, None
            h, _ = jax.lax.scan(inner, c, None, length=3)
            return h, ()
        c, _ = jax.lax.scan(layer, x, None, length=5)
        return c.sum()

    cost = analysis.trace_cost(f, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    assert cost.flops >= 5 * 3 * 2 * 16 ** 3


def test_jaxpr_cost_cond_charges_worst_branch():
    # static trip unknown → the analyzer charges the most expensive branch,
    # not the sum of branches and not the cheap one
    def f(pred, x):
        return jax.lax.cond(pred, lambda a: a @ a, lambda a: a + a, x)

    cost = analysis.trace_cost(
        f, jax.ShapeDtypeStruct((), jnp.bool_),
        jax.ShapeDtypeStruct((32, 32), jnp.float32))
    assert cost.flops == 2 * 32 ** 3


def test_jaxpr_cost_cond_nested_scan_in_branch():
    # branch costs are themselves walked recursively: a scan inside the
    # taken-to-be-worst branch multiplies by its trip count
    def f(pred, x):
        def heavy(a):
            def body(c, _):
                return c @ c, None
            c, _ = jax.lax.scan(body, a, None, length=4)
            return c
        return jax.lax.cond(pred, heavy, lambda a: a, x)

    cost = analysis.trace_cost(
        f, jax.ShapeDtypeStruct((), jnp.bool_),
        jax.ShapeDtypeStruct((16, 16), jnp.float32))
    assert cost.flops == 4 * 2 * 16 ** 3


def test_jaxpr_cost_custom_vjp_primal():
    # custom_vjp primal call carries its body as call_jaxpr — the walker
    # must descend instead of treating the call as a zero-flop leaf
    @jax.custom_vjp
    def f(a, b):
        return a @ b

    def fwd(a, b):
        return a @ b, (a, b)

    def bwd(res, g):
        a, b = res
        return g @ b.T, a.T @ g

    f.defvjp(fwd, bwd)
    cost = analysis.trace_cost(
        f, jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32))
    assert cost.flops == 2 * 8 * 16 * 4


def test_jaxpr_cost_remat_grad_counts_recompute():
    # differentiating through jax.checkpoint re-runs the forward inside the
    # backward pass: the traced grad must cost at least forward + the two
    # backward matmuls (3× a single forward)
    def loss(x):
        return jax.checkpoint(lambda y: (y @ y).sum())(x)

    fwd = analysis.trace_cost(
        loss, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    grad = analysis.trace_cost(
        jax.grad(loss), jax.ShapeDtypeStruct((16, 16), jnp.float32))
    assert fwd.flops == 2 * 16 ** 3
    assert grad.flops >= 3 * fwd.flops


def test_collective_parse():
    hlo = """
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[1024]{0} all-reduce-start(%y), to_apply=%add
  %cp = f32[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
    """
    out = analysis.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 4
    assert out["all-reduce"] == 1024 * 2
    assert out["collective-permute"] == 16 * 4


def test_collective_parse_variadic_tuple():
    # tuple-shaped variadic collectives (several operands on one op) used to
    # be skipped entirely — the ROADMAP parser gap. Async -start tuples
    # interleave (operand, result, context) and count their largest element,
    # not the sum (summing would double-count payload+result).
    hlo = """
  %ar = (f32[128]{0}, s32[64]{0}) all-reduce(%a, %b), replica_groups={}
  %ag = (u8[256]{0}) all-gather(%e), replica_groups={}
  %ags = (f32[8]{0}, f32[16]{0}) all-gather-start(%g), replica_groups={}
  %cps = (f32[100]{0}, f32[100]{0}, u32[], u32[]) collective-permute-start(%h)
  %plain = f32[100]{0} all-reduce(%f), to_apply=%add
    """
    out = analysis.collective_bytes(hlo)
    assert out["all-reduce"] == (128 * 4 + 64 * 4) + 100 * 4
    assert out["all-gather"] == 256 * 1 + 16 * 4   # -start: result, not op+result
    assert out["collective-permute"] == 100 * 4    # not 2× the buffer


# a while loop whose body holds one all-reduce (plus a fusion the body calls
# that holds a collective-permute), and one all-reduce outside the loop —
# the shape XLA emits for a lax.scan-carried collective
_WHILE_HLO = """
%fused_body_inner.9 (p0: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %cp = f32[4,4]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
}

%body.10 (arg.11: (s32[], f32[128])) -> (s32[], f32[128]) {
  %arg.11 = (s32[], f32[128]) parameter(0)
  %ar.body = f32[128]{0} all-reduce(%gte.1), to_apply=%add
  %fus = f32[4,4]{1,0} fusion(%c), kind=kLoop, calls=%fused_body_inner.9
}

%cond.20 (arg.21: (s32[], f32[128])) -> pred[] {
  %arg.21 = (s32[], f32[128]) parameter(0)
}

ENTRY %main.30 (Arg_0.1: f32[128]) -> f32[128] {
  %ar.entry = f32[64]{0} all-reduce(%x), to_apply=%add
  %w = (s32[], f32[128]) while(%tuple), condition=%cond.20, body=%body.10
}
"""


def test_collective_while_body_counts_once_by_default():
    out = analysis.collective_bytes(_WHILE_HLO)
    assert out["all-reduce"] == 128 * 4 + 64 * 4
    assert out["collective-permute"] == 16 * 4


def test_collective_while_body_scalar_trips():
    # scalar while_trips multiplies everything the loop body (transitively)
    # executes — the fusion's collective-permute included — but not the
    # entry-computation all-reduce
    out = analysis.collective_bytes(_WHILE_HLO, while_trips=7)
    assert out["all-reduce"] == 7 * 128 * 4 + 64 * 4
    assert out["collective-permute"] == 7 * 16 * 4


def test_collective_while_body_fold_jaxpr_counts():
    # jaxpr-walker counts: 11 all-reduces total (1 outside + body ran 10×),
    # 10 collective-permutes (all in-loop) → per-kind derived trips
    out = analysis.collective_bytes(
        _WHILE_HLO, while_trips={"all-reduce": 11.0,
                                 "collective-permute": 10.0})
    assert out["all-reduce"] == 10 * 128 * 4 + 64 * 4
    assert out["collective-permute"] == 10 * 16 * 4


# an inner while nested inside an outer while's body: the in-loop set must
# include the inner body transitively, and trip folding treats every in-loop
# occurrence of a kind with one blended multiplier (documented estimate)
_NESTED_WHILE_HLO = """
%inner_body.5 (arg.6: (s32[], f32[32])) -> (s32[], f32[32]) {
  %arg.6 = (s32[], f32[32]) parameter(0)
  %ar.inner = f32[32]{0} all-reduce(%gte.i), to_apply=%add
}

%inner_cond.8 (arg.9: (s32[], f32[32])) -> pred[] {
  %arg.9 = (s32[], f32[32]) parameter(0)
}

%outer_body.10 (arg.11: (s32[], f32[128])) -> (s32[], f32[128]) {
  %arg.11 = (s32[], f32[128]) parameter(0)
  %ar.outer = f32[128]{0} all-reduce(%gte.o), to_apply=%add
  %wi = (s32[], f32[32]) while(%t2), condition=%inner_cond.8, body=%inner_body.5
}

%outer_cond.20 (arg.21: (s32[], f32[128])) -> pred[] {
  %arg.21 = (s32[], f32[128]) parameter(0)
}

ENTRY %main.30 (Arg_0.1: f32[128]) -> f32[128] {
  %ar.entry = f32[64]{0} all-reduce(%x), to_apply=%add
  %w = (s32[], f32[128]) while(%tuple), condition=%outer_cond.20, body=%outer_body.10
}
"""


def test_collective_nested_while_counts_once_by_default():
    out = analysis.collective_bytes(_NESTED_WHILE_HLO)
    assert out["all-reduce"] == 32 * 4 + 128 * 4 + 64 * 4


def test_collective_nested_while_scalar_trips():
    # the inner body is transitively in the in-loop set, so both loop
    # collectives scale; the entry one does not. One scalar applies to all
    # loop bodies (nested trips are NOT compounded — documented estimate).
    out = analysis.collective_bytes(_NESTED_WHILE_HLO, while_trips=3)
    assert out["all-reduce"] == 3 * (32 * 4 + 128 * 4) + 64 * 4


def test_collective_nested_while_fold_jaxpr_counts():
    # jaxpr-walker totals: 1 outside + outer body ran 4× + inner ran 4·6 =
    # 29 expected invocations over 2 in-loop occurrences → blended
    # multiplier (29 − 1) / 2 = 14 on each in-loop payload
    out = analysis.collective_bytes(
        _NESTED_WHILE_HLO, while_trips={"all-reduce": 29.0})
    assert out["all-reduce"] == 64 * 4 + 14 * (32 * 4 + 128 * 4)


def test_collective_fold_from_traced_scan(subproc):
    """End to end: a psum carried by lax.scan compiles to one all-reduce in
    an HLO while body; folding the scan-aware jaxpr counts recovers the
    ×length traffic the plain parse undercounts (ROADMAP open item)."""
    code = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import analysis

mesh = jax.make_mesh((2,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
L = 5
def f(x):
    def body(c, _):
        return jax.lax.psum(c, "x") * 0.5, None
    c, _ = jax.lax.scan(body, x, None, length=L)
    return c
sm = jax.shard_map(f, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
                   check_vma=False)
arg = jax.ShapeDtypeStruct((8, 16), jnp.float32)
hlo = jax.jit(sm).lower(arg).compile().as_text()
cost = analysis.trace_cost(sm, arg)
assert cost.collectives.get("psum") == L, cost.collectives
counts = analysis.hlo_collective_counts(cost)
assert counts == {"all-reduce": float(L)}, counts
legacy = analysis.collective_bytes(hlo)
folded = analysis.collective_bytes(hlo, while_trips=counts)
assert legacy["all-reduce"] > 0
assert folded["all-reduce"] == L * legacy["all-reduce"], (legacy, folded)
print("FOLD_OK")
"""
    out = subproc(code, n_devices=2)
    assert "FOLD_OK" in out

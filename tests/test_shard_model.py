"""Word-sharded model parallelism conformance suite (DESIGN.md §10).

The replicated ring (``n_model_shards=1``) is the bitwise oracle: a P-way
word-sharded session must produce exactly the replicated (phi, psi, z) for
both sampler families — one package per round, round-start snapshots and
uid-keyed counter RNG make every per-token draw independent of which device
executed it. The suite covers:

  * epoch-level parity P=2 / P=4 vs replicated, dense and alias samplers;
  * Trainer kill→resume bitwise with SHARDED checkpoints;
  * resharding-loader round-trips (replicated ckpt → P=2 resume and back);
  * the pure row-permutation algebra of ``training.reshard``;
  * ``collective_bytes`` recognizing the rotation's collective-permutes in
    compiled HLO (regression: rotation traffic must not be invisible to the
    cost model), with trip-folded totals matching the §10 analytic model;
  * by-word probe batching in ``kernels.alias.ops.mh_resample`` being a
    bitwise-free reorder.

Multi-device cases run in subprocesses (``conftest.run_with_devices``); the
mesh is (data=4, model=P), so P=2 needs 8 host devices and P=4 needs 16.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.shard


# Builds one small corpus, runs `run(n_model, sampler)` through the raw ring
# epoch (3 epochs), prints PARITY_OK per case. The replicated baseline runs
# in the SAME process on the first D devices of the same host platform.
PARITY_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.data import synthetic, corpus as corpus_mod
from repro.core import distributed as dist, sparse

corpus, _ = synthetic.lda_corpus(seed=0, n_docs=240, n_topics=10,
                                 vocab_size=180, doc_len_mean=11)
D, K = 4, 12

def run(n_model, sampler, n_epochs=3):
    sc = corpus_mod.shard_corpus(corpus, D, D, K, seed=1,
                                 n_model_shards=n_model)
    if n_model > 1:
        mesh = jax.make_mesh((D, n_model), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((D, 1), ("data", "model"),
                             devices=jax.devices()[:D],
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    phi, psi, wl, dl, uid, z = dist.device_arrays(sc, K)
    cap = sc.word_local.shape[2]
    doc_cap = sparse.suggest_cap(corpus.doc_lengths(), K)
    cfg = dist.RingConfig(
        n_topics=K, vocab_size=corpus.vocab_size,
        rows_per_shard=sc.rows_per_shard, docs_per_shard=sc.docs_per_shard,
        cap=cap, package_len=cap, n_rounds=D, model_shards=n_model,
        sampler=sampler, n_mh=4, doc_topic_cap=doc_cap)
    epoch = dist.make_ring_epoch(mesh, cfg)
    alpha = jnp.full((K,), 50.0 / K, jnp.float32)
    beta = jnp.float32(0.01)
    args = ()
    if sampler == "alias":
        wq, wp, wa = sparse.make_word_tables(phi, psi, beta,
                                             corpus.vocab_size)
        ap, aa = sparse.make_alpha_table(alpha)
        args = (wq, wp, wa, ap, aa)
    for ep in range(n_epochs):
        phi, psi, wl, dl, uid, z = epoch(phi, psi, wl, dl, uid, z, alpha,
                                         beta, jnp.uint32(ep * 977 + 3),
                                         *args)
    phi_full = dist.gather_phi(phi, sc, K)
    wl_h, u_h, z_h = np.asarray(wl), np.asarray(uid), np.asarray(z)
    valid = wl_h >= 0
    z_by_uid = np.zeros(corpus.n_tokens, np.int32)
    z_by_uid[u_h[valid]] = z_h[valid]
    return np.asarray(phi_full), np.asarray(psi), z_by_uid

P = {P}
for sampler in ("dense", "alias"):
    ref = run(1, sampler)
    got = run(P, sampler)
    assert (ref[0] == got[0]).all(), f"{{sampler}} P={{P}}: phi mismatch"
    assert (ref[1] == got[1]).all(), f"{{sampler}} P={{P}}: psi mismatch"
    assert (ref[2] == got[2]).all(), f"{{sampler}} P={{P}}: z mismatch"
    print(f"{{sampler}}:PARITY_OK")
"""


@pytest.mark.parametrize("p,n_dev", [(2, 8), (4, 16)])
def test_epoch_parity_vs_replicated(subproc, p, n_dev):
    out = subproc(PARITY_CODE.format(P=p), n_devices=n_dev, timeout=900)
    assert out.count("PARITY_OK") == 2, out


# Trainer-level: sharded checkpoints kill→resume + reshard round-trips.
TRAINER_CODE = """
import shutil
import numpy as np
from repro.training import Trainer, TrainerConfig, Checkpointing, KillSwitch

def run(n_model, ckpt_dir=None, kill_at=None, resume=False):
    cfg = TrainerConfig(
        n_docs=240, vocab_size=180, n_topics=12, true_topics=10,
        doc_len_mean=11, data_shards=4, model_shards=max(1, n_model),
        n_model_shards=n_model, n_epochs=6, agg_every=3,
        alpha_opt_from=100, sampler="alias",
        ckpt_dir=ckpt_dir, ckpt_every=2, resume=resume, bench_out=None)
    cbs = [Checkpointing()] if ckpt_dir else []
    if kill_at:
        cbs.append(KillSwitch(kill_at))
    tr = Trainer(cfg, callbacks=cbs)
    try:
        tr.fit()
    except SystemExit as e:
        return ("killed", e.code)
    phi = tr.gather_phi()
    psi = np.asarray(tr.state[1])
    wl, uid, z = (np.asarray(tr.state[2]), np.asarray(tr.state[4]),
                  np.asarray(tr.state[5]))
    valid = wl >= 0
    zg = np.zeros(tr.source.n_tokens, np.int32)
    zg[uid[valid]] = z[valid]
    return phi, psi, zg, np.asarray(tr.alpha)

names = ("phi", "psi", "z", "alpha")

# kill -> resume with SHARDED (P=2) checkpoints
d = "/tmp/shard_suite_ck"
shutil.rmtree(d, ignore_errors=True)
assert run(2, ckpt_dir=d, kill_at=4) == ("killed", 17)
got = run(2, ckpt_dir=d, resume=True)
ref2 = run(2)
for a, b, n in zip(ref2, got, names):
    assert (a == b).all(), f"resume P=2: {n} mismatch"
print("RESUME_OK")

# reshard round trip: replicated ckpt -> P=2 resume == uninterrupted P=2
# (== uninterrupted replicated, by the parity above)
d = "/tmp/shard_suite_re1"
shutil.rmtree(d, ignore_errors=True)
assert run(1, ckpt_dir=d, kill_at=4) == ("killed", 17)
got = run(2, ckpt_dir=d, resume=True)
for a, b, n in zip(ref2, got, names):
    assert (a == b).all(), f"reshard 1->2: {n} mismatch"
print("RESHARD_UP_OK")

# and back: P=2 ckpt -> replicated resume
d = "/tmp/shard_suite_re2"
shutil.rmtree(d, ignore_errors=True)
assert run(2, ckpt_dir=d, kill_at=4) == ("killed", 17)
got = run(1, ckpt_dir=d, resume=True)
for a, b, n in zip(ref2, got, names):
    assert (a == b).all(), f"reshard 2->1: {n} mismatch"
print("RESHARD_DOWN_OK")
"""


def test_trainer_resume_and_reshard_roundtrip(subproc):
    out = subproc(TRAINER_CODE, n_devices=8, timeout=900)
    assert "RESUME_OK" in out, out
    assert "RESHARD_UP_OK" in out, out
    assert "RESHARD_DOWN_OK" in out, out


def test_reshard_row_permutation_roundtrip():
    """The slice-major row permutation composes to identity through any
    p_old → p_new → p_old chain, pads excluded."""
    from repro.training import reshard

    rng = np.random.default_rng(0)
    rows_coarse = 23
    for p_a, p_b in [(1, 2), (2, 4), (4, 3), (1, 8)]:
        rows_a = p_a * (-(-rows_coarse // p_a))
        rows_b = p_b * (-(-rows_coarse // p_b))
        arr = rng.integers(0, 100, (4, rows_a, 6)).astype(np.int32)
        # zero the pad rows of layout a (they are never populated)
        ga, gb = reshard.row_permutation(rows_coarse, p_a, rows_a, p_b, rows_b)
        mask = np.zeros(rows_a, bool)
        mask[ga] = True
        arr[:, ~mask, :] = 0
        fwd = reshard.permute_rows(arr, ga, gb, rows_b)
        ga2, gb2 = reshard.row_permutation(rows_coarse, p_b, rows_b,
                                           p_a, rows_a)
        back = reshard.permute_rows(fwd, ga2, gb2, rows_a)
        assert (back == arr).all(), (p_a, p_b)


def test_identity_layout_at_p1():
    """n_model_shards=1 must reproduce the historical replicated layout
    bit-for-bit (the conformance baseline is the existing oracle suite)."""
    from repro.data import corpus as corpus_mod, synthetic

    corpus, _ = synthetic.lda_corpus(seed=3, n_docs=60, n_topics=6,
                                     vocab_size=90, doc_len_mean=7)
    a = corpus_mod.shard_corpus(corpus, 2, 2, 8, seed=5)
    b = corpus_mod.shard_corpus(corpus, 2, 2, 8, seed=5, n_model_shards=1)
    for name in ("word_local", "doc_local", "uid", "z0", "shard_of_word",
                 "local_of_word"):
        assert (getattr(a, name) == getattr(b, name)).all(), name
    assert a.rows_per_shard == b.rows_per_shard
    assert a.word_local.shape == b.word_local.shape


def test_bucket_layout_partitions_tokens_by_slice():
    """P>1 stacks are bucket-major: positions [j·capb, (j+1)·capb) of every
    (s, m) sub-block hold exactly the tokens whose word row lives in model
    slice j (word_local // rpm == j)."""
    from repro.data import corpus as corpus_mod, synthetic

    corpus, _ = synthetic.lda_corpus(seed=3, n_docs=120, n_topics=6,
                                     vocab_size=90, doc_len_mean=9)
    P = 3
    sc = corpus_mod.shard_corpus(corpus, 2, 2, 8, seed=5, n_model_shards=P)
    assert sc.n_model_shards == P
    assert sc.rows_per_shard % P == 0
    rpm = sc.rows_per_shard // P
    cap = sc.word_local.shape[-1]
    assert cap % P == 0
    capb = cap // P
    wl = np.asarray(sc.word_local)
    for j in range(P):
        bucket = wl[:, :, j * capb:(j + 1) * capb]
        real = bucket[bucket >= 0]
        assert (real // rpm == j).all(), j
    # every real token present exactly once, by uid
    uid = np.asarray(sc.uid)[wl >= 0]
    assert len(np.unique(uid)) == corpus.n_tokens


# collective_bytes regression: a compiled rotation round's ppermutes must be
# visible to the cost model, and trip-folding must match the §10 analytics.
COLLECTIVE_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.data import synthetic, corpus as corpus_mod
from repro.core import distributed as dist
from repro.dist import analysis

corpus, _ = synthetic.lda_corpus(seed=0, n_docs=240, n_topics=10,
                                 vocab_size=180, doc_len_mean=11)
D, K, P = 4, 12, 2
sc = corpus_mod.shard_corpus(corpus, D, D, K, seed=1, n_model_shards=P)
mesh = jax.make_mesh((D, P), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
phi, psi, wl, dl, uid, z = dist.device_arrays(sc, K)
cap = sc.word_local.shape[2]
cfg = dist.RingConfig(n_topics=K, vocab_size=corpus.vocab_size,
                      rows_per_shard=sc.rows_per_shard,
                      docs_per_shard=sc.docs_per_shard,
                      cap=cap, package_len=cap, n_rounds=D, model_shards=P)
epoch = dist.make_ring_epoch(mesh, cfg)
alpha = jnp.full((K,), 50.0 / K, jnp.float32)
args = (phi, psi, wl, dl, uid, z, alpha, jnp.float32(0.01), jnp.uint32(3))
hlo = jax.jit(epoch).lower(*args).compile().as_text()

got = analysis.collective_bytes(hlo)
assert got.get("collective-permute", 0) > 0, got

cost = analysis.trace_cost(epoch, *args)
# per epoch: D rounds x (3 stack planes + z re-ship) data hops
#          + D rounds x (P-1) model hops x 2 gathered planes
expect_n = D * 4 + D * (P - 1) * 2
assert cost.collectives.get("ppermute") == expect_n, cost.collectives
counts = analysis.hlo_collective_counts(cost)
assert counts.get("collective-permute") == expect_n, counts
folded = analysis.collective_bytes(hlo, while_trips=counts)
capb = cap // P
per_hop = D * capb * 4              # one [1, D, capb] int32/u32 plane
assert folded["collective-permute"] == expect_n * per_hop, (
    folded, expect_n * per_hop)
assert folded["collective-permute"] > got["collective-permute"]
print("COLLECTIVE_OK", folded["collective-permute"])
"""


def test_collective_bytes_sees_rotation_permutes(subproc):
    out = subproc(COLLECTIVE_CODE, n_devices=8, timeout=900)
    assert "COLLECTIVE_OK" in out, out


def test_model_shard_report_paper_scale():
    """The §10 analytic model: per-device Φ+tables shrink ~P×; the paper's
    10⁵×10⁶ regime fits 16 GB HBM at P=8 on a 16-ring."""
    from repro.dist import analysis

    base = analysis.model_shard_report(100_000, 1_000_000, 16, 1, 4.5e9,
                                       docs_per_shard=4096, doc_topic_cap=64)
    p8 = analysis.model_shard_report(100_000, 1_000_000, 16, 8, 4.5e9,
                                     docs_per_shard=4096, doc_topic_cap=64)
    model_b = lambda r: (r["phi_bytes_per_device"]
                         + r["tables_bytes_per_device"])
    assert model_b(base) / model_b(p8) == pytest.approx(8.0, rel=1e-3)
    assert base["hbm_bytes_per_device"] > 16e9
    assert p8["hbm_bytes_per_device"] < 16e9
    assert base["theta_bytes_per_device"] == p8["theta_bytes_per_device"]
    # rotation traffic stays bounded (never worse than replicated here)
    assert (p8["rotation_bytes_per_epoch"]
            <= 1.5 * base["rotation_bytes_per_epoch"])


def test_mh_by_word_batching_is_bitwise_free():
    """Stable-sorting probes by word before dispatch must not change any
    sampled z (uid-keyed counters; snapshot reads)."""
    import jax.numpy as jnp

    from repro.core import sparse
    from repro.kernels.alias import ops

    rng = np.random.default_rng(0)
    R, K, T, Dn = 10, 12, 64, 16
    phi = jnp.asarray(rng.integers(0, 9, (R, K)), jnp.int32)
    psi = phi.sum(0)
    alpha = jnp.asarray(rng.random(K), jnp.float32)
    wq, wp, wa = sparse.make_word_tables(phi[None], psi, jnp.float32(0.01), R)
    ap, aa = sparse.make_alpha_table(alpha)
    dt = jnp.asarray(rng.integers(0, K, (Dn, 6)), jnp.int32)
    dc = jnp.asarray(rng.integers(1, 4, (Dn, 6)), jnp.int32)
    w = jnp.asarray(rng.integers(0, R, T), jnp.int32)
    d = jnp.asarray(rng.integers(0, Dn, T), jnp.int32)
    z = jnp.asarray(rng.integers(0, K, T), jnp.int32)
    uid = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.uint32)
    outs = {}
    for batch in (False, True):
        for force in ("ref", "interpret"):
            outs[(batch, force)] = np.asarray(ops.mh_resample(
                phi, psi, dt, dc, wq[0], wp[0], wa[0], alpha, ap, aa,
                w, d, z, uid, 7, jnp.float32(0.01), R, 4,
                force=force, batch_by_word=batch))
    ref = outs[(False, "ref")]
    for k, v in outs.items():
        assert (v == ref).all(), k


def test_config_validation():
    """n_model_shards wiring: geometry rules + ring_size semantics."""
    from repro.training import TrainerConfig

    cfg = TrainerConfig(data_shards=4, model_shards=2, n_model_shards=2)
    assert cfg.ring_size == 4              # rotation over "data" only
    assert cfg.n_devices == 8
    rep = TrainerConfig(data_shards=4, model_shards=2)
    assert rep.ring_size == 8              # flattened ring, historical
    with pytest.raises(ValueError, match="model_shards"):
        TrainerConfig(data_shards=4, model_shards=4, n_model_shards=2)
    with pytest.raises(ValueError, match="package_len"):
        TrainerConfig(data_shards=4, model_shards=2, n_model_shards=2,
                      package_len=16)

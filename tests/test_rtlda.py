"""RT-LDA: R-cache correctness, Eq.4 path vs dense max, accuracy vs fold-in."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gibbs, lda, rtlda
from repro.data import corpus as corpus_mod
from repro.data import synthetic


def _model(K=10, V=200, iters=30):
    corpus, truth = synthetic.lda_corpus(seed=0, n_docs=400, n_topics=8,
                                         vocab_size=V, doc_len_mean=10)
    wi, di = corpus_mod.pad_corpus(corpus.word_ids, corpus.doc_ids, 256)
    valid = wi >= 0
    state = lda.init_state(jax.random.key(0), jnp.array(wi[valid]), K, V)
    z = np.zeros(len(wi), np.int32)
    z[valid] = np.array(state.z)
    state = lda.LDAState(state.phi, state.psi, jnp.array(z), state.alpha, state.beta)
    for it in range(iters):
        state = gibbs.gibbs_epoch(state, jnp.array(wi), jnp.array(di),
                                  corpus.n_docs, V, seed=it * 7 + 1, block_size=256)
    return corpus, truth, state


def _queries(V, n=24, Ld=10, seed=3):
    test_c, truth = synthetic.lda_corpus(seed=seed, n_docs=n, n_topics=8,
                                         vocab_size=V, query_like=True)
    qs = np.full((n, Ld), -1, np.int32)
    for d in range(n):
        toks = test_c.word_ids[test_c.doc_ids == d][:Ld]
        qs[d, :len(toks)] = toks
    return jnp.array(qs), test_c


def test_r_cache_is_prior_argmax():
    _, _, state = _model()
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    pvk = np.asarray(model.pvk)
    prior = pvk * np.asarray(model.alpha)[None, :]
    np.testing.assert_array_equal(np.asarray(model.r_topic), prior.argmax(axis=1))
    np.testing.assert_allclose(np.asarray(model.r_value), prior.max(axis=1), rtol=1e-6)


def test_sparse_path_close_to_dense():
    corpus, truth, state = _model()
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    qs, _ = _queries(state.vocab_size)
    pkd_s = rtlda.rtlda_infer_batch(model, qs, seed=1, n_iters=6, n_trials=2)
    pkd_d = rtlda.rtlda_infer_dense(model, qs, n_iters=6)
    cos = np.asarray(jnp.sum(pkd_s * pkd_d, 1)
                     / (jnp.linalg.norm(pkd_s, axis=1)
                        * jnp.linalg.norm(pkd_d, axis=1)))
    assert cos.mean() > 0.9, cos.mean()


def test_distributions_normalized_and_finite():
    _, _, state = _model(iters=10)
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    qs, _ = _queries(state.vocab_size)
    for fn in (lambda: rtlda.rtlda_infer_batch(model, qs, seed=2, n_trials=3),
               lambda: rtlda.rtlda_infer_dense(model, qs)):
        pkd = np.asarray(fn())
        assert np.isfinite(pkd).all()
        np.testing.assert_allclose(pkd.sum(axis=1), 1.0, rtol=1e-4)
        assert (pkd >= 0).all()


def test_rtlda_close_to_gibbs_fold_in():
    """Paper Fig. 5B: RT-LDA accuracy ≈ SparseLDA (tolerable loss)."""
    corpus, truth, state = _model(iters=30)
    V, K = state.vocab_size, state.n_topics
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    qs, test_c = _queries(V, n=40)
    pkd_rt = rtlda.rtlda_infer_batch(model, qs, seed=2, n_iters=6, n_trials=3)

    z0 = jnp.zeros((test_c.n_tokens,), jnp.int32)
    z, theta = gibbs.fold_in(state.phi, state.psi, state.alpha, state.beta,
                             jnp.array(test_c.word_ids), jnp.array(test_c.doc_ids),
                             z0, test_c.n_docs, V, seed=4, n_sweeps=15)
    pkd_gibbs = np.asarray(lda.theta_hat(theta, state.alpha))

    # predictive log-prob of test tokens under each inferred mixture
    pvk = np.asarray(lda.phi_hat(state.phi, state.beta))
    def score(pkd):
        p = np.einsum("tk,tk->t", pvk[test_c.word_ids],
                      np.asarray(pkd)[test_c.doc_ids])
        return float(np.log(np.maximum(p, 1e-30)).mean())
    s_rt, s_gibbs = score(pkd_rt), score(pkd_gibbs)
    # RT-LDA may lose a little accuracy but must be in the same regime
    assert s_rt > s_gibbs - 0.5, (s_rt, s_gibbs)


def test_parallel_trials_help_or_tie():
    corpus, truth, state = _model(iters=20)
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    qs, test_c = _queries(state.vocab_size, n=40)
    pvk = np.asarray(lda.phi_hat(state.phi, state.beta))

    def score(pkd):
        p = np.einsum("tk,tk->t", pvk[test_c.word_ids],
                      np.asarray(pkd)[test_c.doc_ids])
        return float(np.log(np.maximum(p, 1e-30)).mean())

    s1 = score(rtlda.rtlda_infer_batch(model, qs, seed=2, n_trials=1))
    s4 = score(rtlda.rtlda_infer_batch(model, qs, seed=2, n_trials=4))
    assert s4 > s1 - 0.05

"""LM family: flash oracle, decode≡forward, MoE dispatch oracle, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lm_archs import small_lm
from repro.models import attention, moe as moe_mod, transformer as tf
from repro.optim.adamw import AdamW

RNG = np.random.default_rng(3)


def _dense_attn_ref(q, k, v, prefix=0):
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    kk = attention._repeat_kv(k, H // k.shape[2])
    vv = attention._repeat_kv(v, H // v.shape[2])
    s = jnp.einsum("bqhd,bkhd->bhqk", q * Dh ** -0.5, kk)
    qpos = (Sk - Sq) + jnp.arange(Sq)
    mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1), vv)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,Dh,qc,kc", [
    (2, 128, 128, 4, 2, 32, 64, 64),
    (1, 65, 65, 2, 2, 16, 32, 32),
    (2, 17, 81, 4, 1, 8, 32, 16),
    (1, 256, 256, 8, 8, 64, 256, 64),
])
def test_flash_matches_dense(B, Sq, Sk, H, KV, Dh, qc, kc):
    q = jnp.array(RNG.normal(size=(B, Sq, H, Dh)).astype(np.float32))
    k = jnp.array(RNG.normal(size=(B, Sk, KV, Dh)).astype(np.float32))
    v = jnp.array(RNG.normal(size=(B, Sk, KV, Dh)).astype(np.float32))
    out = attention.flash_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    ref = _dense_attn_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_gradients_match_dense():
    B, S, H, KV, Dh = 1, 64, 2, 1, 16
    q = jnp.array(RNG.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.array(RNG.normal(size=(B, S, KV, Dh)).astype(np.float32))
    v = jnp.array(RNG.normal(size=(B, S, KV, Dh)).astype(np.float32))
    g1 = jax.grad(lambda q: attention.flash_attention(
        q, k, v, q_chunk=16, kv_chunk=16).sum())(q)
    g2 = jax.grad(lambda q: _dense_attn_ref(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=3e-5)


def test_decode_consistent_with_forward():
    cfg = small_lm()
    params = tf.init_params(cfg, jax.random.key(0))
    toks = jnp.array(RNG.integers(0, cfg.vocab_size, (4, 64)), jnp.int32)
    logits_p, cache = tf.prefill(cfg, params, toks, max_len=96)
    cur = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    seq = toks
    for step in range(3):
        nxt, logits_d, cache = tf.decode_step(cfg, params, cur, cache,
                                              jnp.int32(64 + step))
        seq = jnp.concatenate([seq, cur], axis=1)
        x, head, _ = tf.forward(cfg, params, seq)
        ref = x[:, -1].astype(jnp.float32) @ head.astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                                   atol=1e-4)
        cur = nxt


def test_moe_matches_dense_expert_sum():
    """With capacity ≥ T·k (no drops), sort-dispatch == dense weighted experts."""
    cfg = moe_mod.MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                            capacity_factor=4.0)
    d, T = 8, 24
    key = jax.random.key(1)
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (d, 4)),
        "w1": jax.random.normal(ks[1], (4, d, 16)) * 0.3,
        "w3": jax.random.normal(ks[2], (4, d, 16)) * 0.3,
        "w2": jax.random.normal(ks[3], (4, 16, d)) * 0.3,
    }
    x = jax.random.normal(ks[4], (T, d))
    out, aux = moe_mod.moe_ffn(params, x, cfg)

    # dense oracle: run every expert on every token, combine with top-k gates
    probs = jax.nn.softmax(x @ params["router"], axis=-1)
    gate, expert = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    all_out = jnp.stack([
        (jax.nn.silu(x @ params["w1"][e]) * (x @ params["w3"][e])) @ params["w2"][e]
        for e in range(4)], axis=1)                      # [T, E, d]
    ref = jnp.einsum("tk,tkd->td", gate,
                     jnp.take_along_axis(all_out, expert[..., None], axis=1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_are_bounded():
    cfg = moe_mod.MoEConfig(n_experts=4, top_k=1, d_ff_expert=8,
                            capacity_factor=0.5)
    d, T = 4, 64
    key = jax.random.key(2)
    params = {
        "router": jax.random.normal(key, (d, 4)),
        "w1": jnp.ones((4, d, 8)) * 0.1,
        "w3": jnp.ones((4, d, 8)) * 0.1,
        "w2": jnp.ones((4, 8, d)) * 0.1,
    }
    x = jax.random.normal(jax.random.key(3), (T, d))
    out, _ = moe_mod.moe_ffn(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens produce zero output rows — at capacity 0.5 some survive
    nonzero = (np.abs(np.asarray(out)).sum(axis=1) > 0).mean()
    assert 0.3 < nonzero <= 1.0


@pytest.mark.parametrize("moe", [False, True])
def test_small_lm_trains(moe):
    cfg = small_lm(moe=moe)
    params = tf.init_params(cfg, jax.random.key(0))
    toks = jnp.array(RNG.integers(0, cfg.vocab_size, (4, 64)), jnp.int32)
    labels = jnp.roll(toks, -1, axis=1)
    opt = AdamW(lr=3e-3)
    ost = opt.init(params)
    loss_fn = jax.jit(lambda p: tf.lm_loss(cfg, p, toks, labels))

    @jax.jit
    def step(p, o):
        g = jax.grad(lambda pp: tf.lm_loss(cfg, pp, toks, labels))(p)
        return opt.update(g, o, p)

    l0 = float(loss_fn(params))
    for _ in range(15):
        params, ost = step(params, ost)
    l1 = float(loss_fn(params))
    assert np.isfinite(l1) and l1 < l0


def test_wsd_checkpointable_config_smoke():
    """MiniCPM-style: qk_norm off, tied embeddings, GQA ratio > 1."""
    cfg = small_lm()
    params = tf.init_params(cfg, jax.random.key(1))
    toks = jnp.array(RNG.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    x, head, aux = tf.forward(cfg, params, toks)
    assert x.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(x)).all()

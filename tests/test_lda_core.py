"""LDA math + single-device blocked Gibbs: invariants, convergence, recovery."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import gibbs, lda
from repro.data import corpus as corpus_mod
from repro.data import synthetic


def _trained_state(n_iters=25, n_docs=400, n_topics_true=8, K=10, V=200):
    corpus, truth = synthetic.lda_corpus(
        seed=0, n_docs=n_docs, n_topics=n_topics_true, vocab_size=V, doc_len_mean=10)
    wi, di = corpus_mod.pad_corpus(corpus.word_ids, corpus.doc_ids, 256)
    valid = wi >= 0
    state = lda.init_state(jax.random.key(0), jnp.array(wi[valid]), K, V)
    z = np.zeros(len(wi), np.int32)
    z[valid] = np.array(state.z)
    state = lda.LDAState(state.phi, state.psi, jnp.array(z), state.alpha, state.beta)
    for it in range(n_iters):
        state = gibbs.gibbs_epoch(state, jnp.array(wi), jnp.array(di),
                                  corpus.n_docs, V, seed=it * 31 + 5, block_size=256)
    return corpus, truth, state, wi, di, valid


def test_counts_conserved_and_consistent():
    corpus, truth, state, wi, di, valid = _trained_state(n_iters=5)
    phi, psi = lda.build_counts(jnp.array(wi[valid]),
                                jnp.array(np.array(state.z)[valid]),
                                state.n_topics, state.vocab_size)
    assert (np.asarray(phi) == np.asarray(state.phi)).all()
    assert (np.asarray(psi) == np.asarray(state.psi)).all()
    assert int(state.psi.sum()) == int(valid.sum())
    assert (np.asarray(state.phi).sum(axis=0) == np.asarray(state.psi)).all()


def test_log_likelihood_improves():
    corpus, truth, state0, wi, di, valid = _trained_state(n_iters=0)
    ll0 = float(lda.word_log_likelihood(state0.phi, state0.psi, state0.beta))
    _, _, state1, _, _, _ = _trained_state(n_iters=20)
    ll1 = float(lda.word_log_likelihood(state1.phi, state1.psi, state1.beta))
    assert ll1 > ll0 + 100.0


def test_perplexity_better_than_uniform():
    corpus, truth, state, wi, di, valid = _trained_state(n_iters=25)
    ppx = lda.perplexity(state.phi, state.psi, state.beta, state.alpha,
                         jnp.array(wi[valid]), jnp.array(di[valid]),
                         jnp.array(np.asarray(state.z)[valid]), corpus.n_docs)
    assert ppx < corpus.vocab_size * 0.8       # uniform model would be V


def test_topic_recovery():
    """Trained topics should align with the generator's topics (greedy match)."""
    corpus, truth, state, wi, di, valid = _trained_state(n_iters=40, K=8,
                                                         n_topics_true=8)
    learned = np.asarray(lda.phi_hat(state.phi, state.beta)).T     # [K, V]
    true = truth.topic_word                                        # [K*, V]
    sim = learned @ true.T / (
        np.linalg.norm(learned, axis=1, keepdims=True)
        * np.linalg.norm(true, axis=1, keepdims=True).T + 1e-12)
    # each true topic should have some learned topic with decent cosine
    assert float(sim.max(axis=0).mean()) > 0.5


def test_fold_in_reduces_test_perplexity():
    corpus, truth, state, wi, di, valid = _trained_state(n_iters=25)
    test_c, _ = synthetic.lda_corpus(seed=5, n_docs=60, n_topics=8,
                                     vocab_size=200, doc_len_mean=10)
    K = state.n_topics
    z0 = jnp.zeros((test_c.n_tokens,), jnp.int32)
    lp0 = lda.predictive_log_prob(state.phi, state.psi, state.beta, state.alpha,
                                  jnp.array(test_c.word_ids),
                                  jnp.array(test_c.doc_ids), z0, test_c.n_docs)
    z, _ = gibbs.fold_in(state.phi, state.psi, state.alpha, state.beta,
                         jnp.array(test_c.word_ids), jnp.array(test_c.doc_ids),
                         z0, test_c.n_docs, 200, seed=3, n_sweeps=10)
    lp1 = lda.predictive_log_prob(state.phi, state.psi, state.beta, state.alpha,
                                  jnp.array(test_c.word_ids),
                                  jnp.array(test_c.doc_ids), z, test_c.n_docs)
    assert float(lp1) > float(lp0)


def test_pmi_favors_trained_model():
    corpus, truth, state, wi, di, valid = _trained_state(n_iters=40)
    pmi_trained = lda.topic_pmi(np.asarray(state.phi), corpus.word_ids,
                                corpus.doc_ids, corpus.n_docs, top_n=5)
    rng = np.random.default_rng(0)
    random_phi = rng.integers(0, 20, np.asarray(state.phi).shape)
    pmi_rand = lda.topic_pmi(random_phi, corpus.word_ids, corpus.doc_ids,
                             corpus.n_docs, top_n=5)
    assert pmi_trained.mean() > pmi_rand.mean()


@given(n_tokens=st.integers(10, 300), k=st.integers(2, 12), v=st.integers(5, 50),
       seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_build_counts_property(n_tokens, k, v, seed):
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.integers(0, v, n_tokens), jnp.int32)
    z = jnp.array(rng.integers(0, k, n_tokens), jnp.int32)
    phi, psi = lda.build_counts(w, z, k, v)
    assert int(phi.sum()) == n_tokens
    assert (np.asarray(phi).sum(axis=0) == np.asarray(psi)).all()
    assert (np.asarray(phi) >= 0).all()


def test_gibbs_epoch_is_deterministic():
    """Counter-based RNG: same seed ⇒ identical trajectory (replay property)."""
    _, _, s1, wi, di, _ = _trained_state(n_iters=3)
    _, _, s2, _, _, _ = _trained_state(n_iters=3)
    assert (np.asarray(s1.z) == np.asarray(s2.z)).all()
    assert (np.asarray(s1.phi) == np.asarray(s2.phi)).all()

"""Alias-MH sampler through the ring / Trainer layers (DESIGN.md §9).

The kernel-level contracts live in test_kernels_alias.py; here the sparse
sampling path runs through ``build_epoch_body`` (multi-device subprocess) and
the Trainer (table rebuild cadence, determinism, checkpoint-derived tables).
"""
import numpy as np
import pytest

pytestmark = pytest.mark.kernels


ALIAS_RING_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.data import synthetic, corpus as corpus_mod
from repro.core import distributed as dist, lda, sparse

corpus, truth = synthetic.lda_corpus(seed=0, n_docs=400, n_topics=12, vocab_size=300, doc_len_mean=6)
mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
M, K = 8, 32
sc = corpus_mod.shard_corpus(corpus, M, M, K, seed=1)
phi, psi, wl, dl, uid, z = dist.device_arrays(sc, K)
cap_p = sparse.suggest_cap(corpus.doc_lengths(), K)
assert cap_p < K, (cap_p, K)   # the production pair-row regime (cap < K)
cfg = dist.RingConfig(n_topics=K, vocab_size=corpus.vocab_size, rows_per_shard=sc.rows_per_shard,
                      docs_per_shard=sc.docs_per_shard, cap=sc.word_local.shape[2],
                      package_len=sc.word_local.shape[2]//2, n_rounds=M,
                      sampler="alias", n_mh=4, doc_topic_cap=cap_p)
epoch = dist.make_ring_epoch(mesh, cfg)
alpha = jnp.full((K,), 50.0/K, jnp.float32); beta = jnp.float32(0.01)
ll0 = float(lda.word_log_likelihood(jnp.asarray(dist.gather_phi(phi, sc, K)), psi, beta))
tabs = None
for ep in range(9):
    if ep % 3 == 0:    # the aggregation-boundary rebuild cadence
        tabs = sparse.make_tables(phi, psi, alpha, beta, corpus.vocab_size)
    phi, psi, wl, dl, uid, z = epoch(phi, psi, wl, dl, uid, z, alpha, beta, jnp.uint32(ep*977+3), *tabs)
phi_full = dist.gather_phi(phi, sc, K)
ll1 = float(lda.word_log_likelihood(jnp.asarray(phi_full), psi, beta))
assert ll1 > ll0, (ll0, ll1)
assert int(np.asarray(psi).sum()) == corpus.n_tokens
assert int(phi_full.sum()) == corpus.n_tokens
wl_h, z_h = np.asarray(wl), np.asarray(z)
valid = wl_h >= 0
phi_chk = np.zeros((M, sc.rows_per_shard, K), np.int32)
for m in range(M):
    np.add.at(phi_chk[m], (wl_h[:, m][valid[:, m]], z_h[:, m][valid[:, m]]), 1)
assert (phi_chk == np.asarray(phi)).all(), "phi inconsistent with traveling z"
assert (np.asarray(phi).sum(axis=(0, 1)) == np.asarray(psi)).all()
print("ALIAS_RING_OK", ll0, ll1)
"""


def test_alias_ring_epoch_multidevice(subproc):
    out = subproc(ALIAS_RING_CODE, n_devices=8)
    assert "ALIAS_RING_OK" in out


def _fit(seed=0, **kw):
    from repro.training import AlphaOptimizer, Trainer, TrainerConfig

    # n_topics > max doc length ⇒ suggest_cap yields cap < K: the trainer
    # tests run the production pair-row regime, not the cap == K easy case
    cfg = TrainerConfig(n_docs=300, vocab_size=150, n_topics=32,
                        true_topics=8, doc_len_mean=6, n_epochs=7,
                        agg_every=3, alpha_opt_from=3, seed=seed,
                        sampler="alias", n_mh=4, **kw)
    tr = Trainer(cfg, callbacks=[AlphaOptimizer()])
    tr.log = lambda m: None
    tr.fit()
    return tr


def test_trainer_alias_counts_and_progress():
    tr = _fit()
    assert tr.ring_cfg.doc_topic_cap < tr.config.n_topics  # cap < K regime
    phi = np.asarray(tr.state[0])
    psi = np.asarray(tr.state[1])
    wl, z = np.asarray(tr.state[2]), np.asarray(tr.state[5])
    valid = wl >= 0
    assert int(psi.sum()) == int(valid.sum())
    assert (phi.sum(axis=(0, 1)) == psi).all()
    assert np.isfinite(tr.log_likelihood())
    # the sampler must actually have moved assignments
    assert (np.asarray(tr.state[5]) != 0).any()


def test_trainer_alias_deterministic():
    a = _fit(seed=3)
    b = _fit(seed=3)
    np.testing.assert_array_equal(np.asarray(a.state[5]),
                                  np.asarray(b.state[5]))
    np.testing.assert_array_equal(np.asarray(a.state[0]),
                                  np.asarray(b.state[0]))


def test_trainer_alias_streaming_runs():
    tr = _fit(n_segments=3)
    assert np.isfinite(tr.log_likelihood())
    psi = np.asarray(tr.state[1])
    assert int(psi.sum()) == int(tr.source.n_tokens)


@pytest.mark.parametrize("ckpt_every", [2, 3])
def test_trainer_alias_kill_resume_bitwise(tmp_path, ckpt_every):
    """Kill → resume must replay bit-for-bit. ckpt_every=2 lands MID table-
    staleness window (rebuilds at epoch starts 3 and 6 under agg_every=3):
    the proposal tables must ride in the checkpoint — rebuilding from the
    restored Φ would hand the resumed run fresher proposals than the
    uninterrupted one sampled with. ckpt_every=3 ALIGNS the save with a
    rebuild boundary: the resumed run must re-derive the due rebuild from
    the restored state (= the uninterrupted run's epoch-start state)."""
    from repro.training import (Checkpointing, KillSwitch, Metrics, Trainer,
                                TrainerConfig)

    def build(ck, resume=False, kill=None):
        cfg = TrainerConfig(n_docs=240, vocab_size=150, n_topics=32,
                            true_topics=8, doc_len_mean=6, n_epochs=7,
                            agg_every=3, alpha_opt_from=3, ckpt_dir=str(ck),
                            ckpt_every=ckpt_every, resume=resume,
                            sampler="alias", n_mh=4)
        cbs = [Checkpointing()]
        if kill:
            cbs.append(KillSwitch(kill))
        cbs.append(Metrics(printer=lambda m: None))
        tr = Trainer(cfg, callbacks=cbs)
        tr.log = lambda m: None
        return tr

    gold_tr = build(tmp_path / "gold")
    gold_tr.fit()
    gold = [np.asarray(x) for x in gold_tr.state]

    ck = tmp_path / "ck"
    with pytest.raises(SystemExit):
        build(ck, kill=5).fit()
    res_tr = build(ck, resume=True)
    res_tr.fit()
    for i, (a, b) in enumerate(zip(gold, [np.asarray(x)
                                          for x in res_tr.state])):
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"state leaf {i} diverged")
    np.testing.assert_array_equal(np.asarray(gold_tr.alpha),
                                  np.asarray(res_tr.alpha))


def test_config_validates_sampler_fields():
    from repro.training import TrainerConfig

    with pytest.raises(ValueError):
        TrainerConfig(sampler="fancy")
    with pytest.raises(ValueError):
        TrainerConfig(sampler="alias", n_mh=0)
    with pytest.raises(ValueError):
        TrainerConfig(kernel_mode="maybe")

"""Live train→publish→serve refresh: the loop Peacock runs in production.

    PYTHONPATH=src python examples/live_refresh.py

The paper's industrial deployment (§3.1–§3.3) trains continuously and feeds
fresh RT-LDA models to online serving. This example runs that loop on one
host:

  1. a ``Trainer`` publishes version 0 of the model before the first epoch
     (``ModelPublisher``: gather Φ → shared dedup distance pass → merge →
     RT-LDA build → atomic versioned snapshot);
  2. a ``TopicEngine`` starts serving from snapshot v0 while a background
     ``SnapshotWatcher`` polls the snapshot directory;
  3. training continues; every publish boundary ships a new version, which
     the watcher hot-swaps into the engine — mid-traffic, lock-free, zero
     dropped requests (a background client submits queries the whole time);
  4. the engine's ``stats().model_version`` shows the refresh happening.
"""
import tempfile
import threading
import time

import numpy as np

from repro.checkpoint import snapshots
from repro.serving import SnapshotWatcher, TopicEngine
from repro.training import Metrics, ModelPublisher, Trainer, TrainerConfig


def main():
    snap_dir = tempfile.mkdtemp(prefix="peacock_snapshots_")
    cfg = TrainerConfig(n_docs=1200, vocab_size=400, n_topics=24,
                        true_topics=16, doc_len_mean=9, n_epochs=10,
                        alpha_opt_from=4)
    publisher = ModelPublisher(snap_dir, every=3)
    trainer = Trainer(cfg, callbacks=[publisher, Metrics()]).setup()

    # publish v0 before fit() so the engine can come up first, the way a
    # serving fleet outlives any one training session (ModelPublisher's
    # ``at_start=True`` does the same from inside the session)
    publisher.publish(trainer, epoch=-1)
    model0, meta0 = snapshots.load_snapshot(snap_dir)
    print(f"[serve] booting engine from snapshot v{meta0['version']} "
          f"(K={model0.alpha.shape[0]})")

    rng = np.random.default_rng(7)
    queries = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in rng.integers(2, 12, size=2000)]

    with TopicEngine(model0, buckets=(4, 8, 16), max_batch=64,
                     max_delay_ms=2.0) as engine:
        engine.swap_model(model0, version=int(meta0["version"]))
        with SnapshotWatcher(snap_dir, engine, poll_s=0.2) as watcher:
            pre = engine.infer(queries[:32])
            v_pre = engine.stats().model_version
            print(f"[serve] {len(pre)} queries answered on model v{v_pre}")

            # background client: open-loop traffic THROUGH the entire
            # training run — every future must resolve across all hot-swaps
            futures, stop = [], threading.Event()

            def client():
                i = 32
                while not stop.is_set():
                    futures.append(engine.submit(queries[i % len(queries)]))
                    i += 1
                    time.sleep(0.005)

            t = threading.Thread(target=client, daemon=True)
            t.start()

            trainer.fit()        # publishes every 3rd epoch + the final model

            assert publisher.last_version is not None
            watcher.wait_for_version(publisher.last_version, timeout_s=10)
            stop.set()
            t.join()

            post = engine.infer(queries[:32])
            s = engine.stats()
            resolved = sum(f.done() for f in futures)
            print(f"[serve] model v{v_pre} → v{s.model_version} "
                  f"({watcher.swaps} hot-swap(s) observed)")
            print(f"[serve] {len(futures)} in-flight queries during "
                  f"training: {resolved} resolved, "
                  f"{len(futures) - resolved} dropped")
            print(f"[serve] p50 {s.p50_ms:.1f} ms  p99 {s.p99_ms:.1f} ms | "
                  f"completed {s.completed}")
            assert resolved == len(futures), "requests dropped across swaps!"
            assert s.model_version == publisher.last_version
            # fresh model, same queries: distributions come from the new Φ
            # (comparable only when dedup kept K unchanged between versions)
            diffs = [np.abs(a.pkd - b.pkd).sum() for a, b in zip(pre, post)
                     if a.pkd.shape == b.pkd.shape]
            if diffs:
                print(f"[serve] mean L1 drift pre→post refresh: "
                      f"{float(np.mean(diffs)):.3f}")

    print(f"[done] versions on disk: {snapshots.snapshot_versions(snap_dir)} "
          f"(rotation keep={publisher.keep})")


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# Must run before jax initializes: the simulated 2-D mesh below is
# (data=2) x (model=4) = 8 host devices.

"""Train a model past the single-device replicated ceiling (DESIGN.md §10).

    PYTHONPATH=src python examples/big_model.py

Every device used to hold its vocab shard's FULL Φ row block plus alias
tables — so the largest trainable K was capped by one device's HBM. This
example sets an artificial per-device model-state budget that the replicated
layout cannot meet at the chosen (K, V), then trains the same session with
``n_model_shards=4``: Φ, the word-proposal tables and the per-word alias
tables split into 4 resident vocabulary slices, token sub-blocks rotate
around the data ring exactly as before, and the sampled model is — by the
shard conformance suite — bitwise what the replicated layout would have
produced. The assertion at the end measures REAL per-device bytes from the
arrays' shardings, not the analytic model; the paper-scale extrapolation
(10⁵ topics × 10⁶ words) is printed via ``dist.analysis.model_shard_report``.
"""
import numpy as np


def per_device_bytes(arr) -> int:
    """Bytes this array pins on ONE device (its largest addressable shard)."""
    return max(s.data.nbytes for s in arr.addressable_shards)


def main():
    from repro.dist import analysis
    from repro.training import Metrics, Trainer, TrainerConfig

    D, P = 2, 4
    cfg = TrainerConfig(
        n_docs=600, vocab_size=2400, n_topics=64, true_topics=24,
        doc_len_mean=10, data_shards=D, model_shards=P, n_model_shards=P,
        sampler="alias", n_epochs=4, alpha_opt_from=100)
    trainer = Trainer(cfg, callbacks=[Metrics()]).setup()

    # the ceiling: per-device model state (Φ int32 + wq/wp f32 + wa int32
    # row slices) a replicated layout would need for this (K, V, D)
    rows_replicated = trainer.sc0.rows_per_shard        # all rows resident
    replicated_need = rows_replicated * cfg.n_topics * 16
    budget = int(0.5 * replicated_need)                 # replicated can't fit
    print(f"[budget] per-device model-state budget {budget/1e3:.0f} kB; "
          f"replicated layout needs {replicated_need/1e3:.0f} kB -> "
          f"does not fit; P={P} slices need "
          f"{replicated_need/P/1e3:.0f} kB -> fits")
    assert replicated_need > budget

    trainer.fit()

    model_state = [trainer.state[0]]                    # Φ
    if trainer._tables is not None:
        model_state += [trainer._tables.wq, trainer._tables.wp,
                        trainer._tables.wa]
    used = sum(per_device_bytes(a) for a in model_state)
    print(f"[measure] per-device Φ+tables actually resident: "
          f"{used/1e3:.0f} kB (budget {budget/1e3:.0f} kB)")
    assert used <= budget, (used, budget)
    assert used * P >= replicated_need                  # it IS the same model

    ll = trainer.log_likelihood()
    print(f"[train] K={cfg.n_topics} on a {D}x{P} mesh: "
          f"final log-likelihood {ll:.0f}")

    # where this matters: the paper's 10^5-topic x 10^6-word regime
    print("[paper scale] K=100k V=1M on a 16-ring:")
    for p in (1, 8):
        r = analysis.model_shard_report(100_000, 1_000_000, 16, p, 4.5e9,
                                        docs_per_shard=4096, doc_topic_cap=64)
        hbm = r["hbm_bytes_per_device"]
        print(f"  P={p}: {hbm/1e9:6.1f} GB/device "
              f"{'(fits 16 GB HBM)' if hbm < 16e9 else '(exceeds 16 GB HBM)'}")


if __name__ == "__main__":
    main()

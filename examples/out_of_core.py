"""Out-of-core training: save a segmented corpus, stream it from disk.

    PYTHONPATH=src python examples/out_of_core.py

The paper's Fig. 3/4 loop — LoadShard / sample / SaveShard — as a user
workflow: build a corpus once, ``save_segments`` it into a DiskSource
directory, then train with only one segment's tokens resident at a time
while a background thread prefetches the next segment. The streamed model is
bitwise identical to the resident one; corpus scale becomes a config knob
(``n_segments``) instead of a RAM limit.
"""
import shutil
import tempfile

import numpy as np

from repro.data import open_segments, save_segments
from repro.training import Metrics, Trainer, TrainerConfig


def main():
    base = dict(n_docs=1500, vocab_size=500, n_topics=16, true_topics=12,
                doc_len_mean=10, n_epochs=6, alpha_opt_from=3)

    # --- 1. resident reference: 4 in-memory segments --------------------
    mem = Trainer(TrainerConfig(n_segments=4, **base),
                  callbacks=[Metrics()])
    mem.fit()

    # --- 2. persist the segmentation as a DiskSource directory ----------
    corpus_dir = tempfile.mkdtemp(prefix="peacock_segments_")
    save_segments(mem.source, corpus_dir)
    src = open_segments(corpus_dir)
    print(f"[save] {corpus_dir}: {src.describe()}")

    # --- 3. stream it back, out of core, prefetch overlapped ------------
    disk = Trainer(TrainerConfig(corpus_dir=corpus_dir, prefetch=True,
                                 **base),
                   callbacks=[Metrics()])
    disk.fit()

    # --- 4. the streamed model is bitwise the resident model ------------
    same_phi = (np.asarray(mem.state[0]) == np.asarray(disk.state[0])).all()
    same_z = (mem._z == disk._z).all()
    print(f"[check] streamed == resident: phi {bool(same_phi)}, "
          f"z {bool(same_z)}")
    seg_s = disk.metrics["segment_s"]
    print(f"[stream] {len(seg_s)} segment swaps, "
          f"{np.mean(seg_s) * 1e3:.1f} ms/segment (prefetch overlapped)")

    shutil.rmtree(corpus_dir, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Serving fleet demo: 4 routed replicas, 2 hot-swaps, zero dropped requests.

    PYTHONPATH=src python examples/fleet_demo.py

Peacock's online serving (§3.2, Fig. 5A) is a fleet of inference backends
behind routing, admission control and a hot-query cache — one
``TopicEngine`` is a single replica of that story. This example runs the
fleet surface (DESIGN.md §13) end to end on one host:

  1. a ``TopicFleet`` of 4 replicas boots from snapshot v0, with the
     segmented-LRU result cache in front (Zipf traffic: the power-law head
     hits the cache, the tail exercises routing + batching);
  2. per-replica ``SnapshotWatcher`` fan-out polls the snapshot directory;
  3. while a background client keeps open-loop traffic in flight, two new
     versions are published — v1 as a full snapshot, v2 as a *delta*
     snapshot (row-diff Φ against v1, the ``ModelPublisher(delta=True)``
     wire format) — and roll across all 4 replicas;
  4. every in-flight future resolves across both swaps (the assertion this
     demo exists for), the cache never serves a result across a version
     boundary, and the final stats show routing spread + hit rate.
"""
import tempfile
import threading
import time

import numpy as np

from repro.checkpoint import snapshots
from repro.core import rtlda
from repro.launch.serve import build_model, make_zipf_traffic, \
    warm_shape_grid
from repro.serving import TopicFleet

BUCKETS = (4, 8, 16)
REPLICAS = 4


def main():
    snap_dir = tempfile.mkdtemp(prefix="peacock_fleet_snapshots_")
    model0, state = build_model(topics=12, vocab=200, train_iters=10)
    snapshots.save_snapshot(snap_dir, 0, model0, {"note": "fleet demo v0"})

    # two refreshed models to roll out mid-traffic: v1 ships full (new Φ
    # counts are dense in the column-normalized P̂(v|k)), v2 is an α-only
    # re-optimization — P̂(v|k) is unchanged, so the row-diff delta ships
    # ZERO Φ rows (the format's best case, and a real publish pattern)
    model1 = rtlda.build_model(state.phi + 1, state.beta, state.alpha)
    model2 = rtlda.build_model(state.phi + 1, state.beta,
                               state.alpha * np.float32(1.25))

    boot, meta0 = snapshots.load_snapshot(snap_dir)
    print(f"[fleet] booting {REPLICAS} replicas from snapshot "
          f"v{meta0['version']} (K={boot.alpha.shape[0]})")

    traffic = make_zipf_traffic(4000, pool=256, vocab=200, buckets=BUCKETS,
                                seed=7)

    with TopicFleet(boot, n_replicas=REPLICAS, buckets=BUCKETS, max_batch=32,
                    max_delay_ms=2.0, cache_mb=4.0, shed=False) as fleet:
        fleet.swap_model(boot, version=int(meta0["version"]))
        fleet.attach_watchers(snap_dir, poll_s=0.1)
        warm_shape_grid(fleet, BUCKETS, 32, 200)
        v_pre = fleet.stats().model_version
        print(f"[fleet] warm on model v{v_pre}, traffic flowing")

        # background client: open-loop Zipf traffic THROUGH both rollouts —
        # every future must resolve across every per-replica hot-swap
        futures, stop = [], threading.Event()

        def client():
            i = 64
            while not stop.is_set():
                futures.append(fleet.submit(traffic[i % len(traffic)]))
                i += 1
                time.sleep(0.002)

        t = threading.Thread(target=client, daemon=True)
        t.start()
        time.sleep(0.3)

        snapshots.save_snapshot(snap_dir, 1, model1, {"note": "refresh"})
        assert fleet.wait_for_version(1, timeout_s=10)
        print("[fleet] hot-swap #1: v0 → v1 rolled across all "
              f"{REPLICAS} replicas (full snapshot)")
        time.sleep(0.6)          # let v1 actually serve before the next roll

        snapshots.save_delta_snapshot(snap_dir, 2, model2, base_version=1,
                                      base_pvk=np.asarray(model1.pvk),
                                      meta={"note": "delta refresh"})
        d = snapshots.read_meta(snap_dir, 2)["delta"]
        assert fleet.wait_for_version(2, timeout_s=10)
        print(f"[fleet] hot-swap #2: v1 → v2 rolled as a delta "
              f"({d['n_rows']}/{d['n_rows_total']} Φ rows shipped)")
        time.sleep(0.3)

        stop.set()
        t.join()
        fleet.flush_all()
        results = [f.result(timeout=30) for f in futures]

        s = fleet.stats()
        shed = sum(getattr(r, "shed", False) for r in results)
        versions = sorted({r.model_version for r in results
                           if not getattr(r, "shed", False)})
        print(f"[fleet] {len(futures)} in-flight requests across 2 "
              f"hot-swaps: {len(results)} resolved, 0 dropped, {shed} shed")
        print(f"[fleet] responses carried model versions {versions} "
              f"(monotonic rollout, live v{s.model_version})")
        print(f"[fleet] routed per replica: {list(s.routed)} | cache hit "
              f"rate {s.hit_rate:.1%} | p50 {s.p50_ms:.1f} ms "
              f"p99 {s.p99_ms:.1f} ms")
        assert len(results) == len(futures), "requests dropped across swaps!"
        assert s.model_version == 2
        assert sum(s.routed) > 0 and s.hit_rate > 0.0

    print(f"[done] versions on disk: {snapshots.snapshot_versions(snap_dir)}")


if __name__ == "__main__":
    main()

"""pCTR example (paper §5.2, Fig. 8): L1 log-linear CTR model ± topic features.

    PYTHONPATH=src python examples/ctr_with_topics.py

Synthetic ad click log whose true CTR depends on (query topic × ad affinity).
The baseline model sees only sparse ad features; the Peacock variant appends
P(k|d) inferred by the trained LDA model. AUC lift mirrors Fig. 8.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gibbs, lda
from repro.data import corpus as corpus_mod, synthetic
from repro.optim import l1_loglinear


def main():
    corpus, truth = synthetic.lda_corpus(seed=0, n_docs=1200, n_topics=16,
                                         vocab_size=400, doc_len_mean=8)
    log = synthetic.click_log(7, corpus, truth, n_impressions=8000)
    sparse = log["ad_feat"][log["ad_idx"]]
    labels = log["label"].astype(np.float32)
    n = len(labels)
    tr, te = slice(0, n * 4 // 5), slice(n * 4 // 5, n)
    print(f"impressions: {n}, positive rate {labels.mean():.3f}")

    def train_ctr(dense, tag):
        st = l1_loglinear.init_state(log["n_ad_features"], dense.shape[1])
        for i in range(200):
            st, loss = l1_loglinear.train_step(
                st, jnp.array(sparse[tr]), jnp.array(dense[tr]),
                jnp.array(labels[tr]), 0.3, 1e-4)
        scores = l1_loglinear.predict(st, jnp.array(sparse[te]),
                                      jnp.array(dense[te]))
        auc = l1_loglinear.auc(np.asarray(scores), labels[te])
        nz = float((np.abs(np.asarray(st.w_sparse)) > 1e-8).mean())
        print(f"  {tag:<28} AUC {auc:.4f}  (nonzero sparse weights {nz:.0%})")
        return auc

    print("baseline (ad features only):")
    base = train_ctr(np.zeros((n, 1), np.float32), "baseline")

    for K in (4, 16, 32):
        wi, di = corpus_mod.pad_corpus(corpus.word_ids, corpus.doc_ids, 512)
        valid = wi >= 0
        state = lda.init_state(jax.random.key(0), jnp.array(wi[valid]), K,
                               corpus.vocab_size)
        z = np.zeros(len(wi), np.int32)
        z[valid] = np.asarray(state.z)
        state = lda.LDAState(state.phi, state.psi, jnp.array(z), state.alpha,
                             state.beta)
        for it in range(25):
            state = gibbs.gibbs_epoch(state, jnp.array(wi), jnp.array(di),
                                      corpus.n_docs, corpus.vocab_size,
                                      seed=it * 17 + 3, block_size=512)
        z0 = jnp.zeros((corpus.n_tokens,), jnp.int32)
        _, theta = gibbs.fold_in(state.phi, state.psi, state.alpha, state.beta,
                                 jnp.array(corpus.word_ids),
                                 jnp.array(corpus.doc_ids), z0, corpus.n_docs,
                                 corpus.vocab_size, seed=5, n_sweeps=8)
        pkd = np.asarray(lda.theta_hat(theta, state.alpha))
        dense = pkd[log["doc_idx"]].astype(np.float32)
        auc = train_ctr(dense, f"+ topic features (K={K})")
        print(f"    → relative AUC lift vs baseline: "
              f"{100*(auc-base)/base:+.2f}% (paper Fig. 8 mechanism)")


if __name__ == "__main__":
    main()

"""Quickstart: train a small Peacock LDA model end to end on one host.

    PYTHONPATH=src python examples/quickstart.py

Generates a synthetic query corpus with known topics, runs the §4.1
preprocessing, trains with blocked collapsed Gibbs + asymmetric-prior
optimization, de-duplicates topics, and prints the learned topics next to the
generator's ground truth.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dedup, gibbs, lda
from repro.data import corpus as corpus_mod, synthetic


def main():
    # --- data ---------------------------------------------------------------
    corpus, truth = synthetic.lda_corpus(
        seed=0, n_docs=1500, n_topics=12, vocab_size=400, doc_len_mean=9)
    print(f"corpus: {corpus.n_docs} docs, {corpus.n_tokens} tokens, "
          f"V={corpus.vocab_size}")

    K = 16
    wi, di = corpus_mod.pad_corpus(corpus.word_ids, corpus.doc_ids, 512)
    valid = wi >= 0

    # --- init + train -------------------------------------------------------
    state = lda.init_state(jax.random.key(0), jnp.array(wi[valid]), K,
                           corpus.vocab_size)
    z = np.zeros(len(wi), np.int32)
    z[valid] = np.asarray(state.z)
    state = lda.LDAState(state.phi, state.psi, jnp.array(z), state.alpha,
                         state.beta)
    dl = dedup.doc_length_histogram(jnp.array(corpus.doc_lengths()))

    for it in range(40):
        state = gibbs.gibbs_epoch(state, jnp.array(wi), jnp.array(di),
                                  corpus.n_docs, corpus.vocab_size,
                                  seed=it * 31 + 7, block_size=512)
        if it >= 20:  # asymmetric prior optimization (paper §3.3)
            omega = dedup.topic_count_histogram(
                jnp.array(di), state.z, jnp.array(wi) >= 0, corpus.n_docs, K)
            alpha = dedup.optimize_alpha(state.alpha, omega, dl, n_iters=5)
            state = lda.LDAState(state.phi, state.psi, state.z, alpha,
                                 state.beta)
        if (it + 1) % 10 == 0:
            ll = float(lda.word_log_likelihood(state.phi, state.psi, state.beta))
            print(f"iter {it+1:3d}  log-likelihood {ll:,.0f}")

    # --- de-duplicate -------------------------------------------------------
    frac = dedup.duplicate_fraction(state.phi, state.beta, 0.5)
    cl, ncl = dedup.cluster_topics(state.phi, state.beta, l1_threshold=0.3)
    print(f"duplicate fraction: {frac:.2f};  {K} topics → {ncl} after L1 merge")

    # --- show topics vs ground truth ----------------------------------------
    pvk = np.asarray(lda.phi_hat(state.phi, state.beta))      # [V, K]
    learned_top = np.argsort(-pvk, axis=0)[:6].T              # [K, 6]
    true_top = np.argsort(-truth.topic_word, axis=1)[:, :6]   # [K*, 6]
    print("\nlearned topics (top words)   | closest true topic")
    for k in np.argsort(-np.asarray(state.psi))[:8]:
        lw = set(int(x) for x in learned_top[k])
        overlaps = [(len(lw & set(int(x) for x in tt)), i)
                    for i, tt in enumerate(true_top)]
        ov, best = max(overlaps)
        print(f"  topic {k:2d}: {sorted(lw)} | true {best:2d} ({ov}/6 shared)")


if __name__ == "__main__":
    main()

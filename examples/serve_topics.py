"""Serving example: async RT-LDA topic features via the TopicEngine.

    PYTHONPATH=src python examples/serve_topics.py

Trains a small model, builds the RT-LDA serving model (R cache, Eq. 3), then
drives the async engine the way a backend would (paper §3.2 / §5.1):

  * ``submit()`` returns a future immediately — the background loop batches
    queries into shape buckets and flushes on fill or deadline slack;
  * responses carry P(k|d) + the top-30 Eq.-5 topic features Peacock injects
    at the head of Weak-AND posting lists, plus serving metadata (bucket,
    truncation, latency, deadline);
  * ``swap_model()`` publishes a refreshed Φ mid-traffic, no downtime;
  * ``stats()`` reports QPS / p50 / p99 / occupancy / deadline-miss rate.
"""
import numpy as np

from repro.core import rtlda
from repro.data import synthetic
from repro.data.fixtures import quick_train
from repro.serving import TopicEngine


def main():
    _, state = quick_train(topics=24, vocab=500, train_iters=30,
                           gen_topics=16)
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    V = state.vocab_size
    print(f"serving model: V={V} K={state.n_topics}; "
          f"R cache = {model.r_topic.shape[0]} entries (1 per word)")

    with TopicEngine(model, buckets=(4, 8, 16, 32), max_batch=128,
                     n_trials=2, max_delay_ms=3.0) as engine:
        # "incoming" query traffic: variable lengths, submitted async
        test_c, _ = synthetic.lda_corpus(seed=100, n_docs=256, n_topics=16,
                                         vocab_size=V, query_like=True)
        queries = [test_c.word_ids[test_c.doc_ids == d]
                   for d in range(test_c.n_docs)]
        futures = [engine.submit(q, deadline_ms=50.0) for q in queries]

        # mid-traffic model refresh (what the train→aggregate loop would push)
        engine.swap_model(rtlda.build_model(state.phi, state.beta,
                                            state.alpha))
        responses = [f.result(timeout=60) for f in futures]

        s = engine.stats()
        print(f"{s.completed} queries | {s.qps:,.0f} QPS | "
              f"p50 {s.p50_ms:.1f} ms  p99 {s.p99_ms:.1f} ms | "
              f"occupancy {s.mean_batch_occupancy:.2f} | "
              f"miss rate {s.deadline_miss_rate:.1%} | "
              f"per-bucket {s.per_bucket}")

        print("\nsample query → top topic features (word ids, Eq. 5 weights):")
        for r, q in list(zip(responses, queries))[:3]:
            print(f"  query {[int(t) for t in q]} [bucket {r.bucket}] → "
                  f"top topics {np.argsort(-r.pkd)[:3]}, "
                  f"features {r.feature_ids[:6]}")


if __name__ == "__main__":
    main()

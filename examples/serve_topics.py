"""Serving example: RT-LDA real-time topic features for incoming queries.

    PYTHONPATH=src python examples/serve_topics.py

Trains a small model, builds the RT-LDA serving model (R cache, Eq. 3), then
runs a batched serving loop over "incoming" queries, producing P(k|d) and the
top-30 Eq.-5 topic features per query — the exact payload Peacock injects into
the Weak-AND posting lists (paper §5.1). Prints latency stats.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import features, gibbs, lda, rtlda
from repro.data import corpus as corpus_mod, synthetic


def train_model(K=24, V=500):
    corpus, truth = synthetic.lda_corpus(seed=0, n_docs=1500, n_topics=16,
                                         vocab_size=V, doc_len_mean=9)
    wi, di = corpus_mod.pad_corpus(corpus.word_ids, corpus.doc_ids, 512)
    valid = wi >= 0
    state = lda.init_state(jax.random.key(0), jnp.array(wi[valid]), K, V)
    z = np.zeros(len(wi), np.int32)
    z[valid] = np.asarray(state.z)
    state = lda.LDAState(state.phi, state.psi, jnp.array(z), state.alpha,
                         state.beta)
    for it in range(30):
        state = gibbs.gibbs_epoch(state, jnp.array(wi), jnp.array(di),
                                  corpus.n_docs, V, seed=it * 13 + 1,
                                  block_size=512)
    return state


def main():
    state = train_model()
    model = rtlda.build_model(state.phi, state.beta, state.alpha)
    print(f"serving model: V={state.vocab_size} K={state.n_topics}; "
          f"R cache = {model.r_topic.shape[0]} entries (1 per word)")

    # batched serving loop over synthetic query traffic
    V, Ld, batch = state.vocab_size, 8, 128
    serve = jax.jit(lambda q, s: features.query_topic_features(
        model, q, seed=s, n_iters=5, n_trials=2, top_n=30))
    rng = np.random.default_rng(5)

    lat = []
    for step in range(8):
        test_c, _ = synthetic.lda_corpus(seed=100 + step, n_docs=batch,
                                         n_topics=16, vocab_size=V,
                                         query_like=True)
        qs = np.full((batch, Ld), -1, np.int32)
        for d in range(batch):
            toks = test_c.word_ids[test_c.doc_ids == d][:Ld]
            qs[d, :len(toks)] = toks
        t0 = time.perf_counter()
        pkd, ids, w = serve(jnp.array(qs), step)
        jax.block_until_ready(w)
        lat.append(time.perf_counter() - t0)

    lat_ms = np.array(lat[1:]) * 1e3   # drop compile step
    print(f"batch={batch}: mean {lat_ms.mean():.1f} ms/batch "
          f"({batch/ (lat_ms.mean()/1e3):.0f} QPS), p99≈{np.quantile(lat_ms, 0.99):.1f} ms")
    print("\nsample query → top topic features (word ids, Eq. 5 weights):")
    for b in range(3):
        q = [t for t in np.asarray(qs[b]) if t >= 0]
        print(f"  query {q} → top topics {np.argsort(-np.asarray(pkd[b]))[:3]}"
              f", features {np.asarray(ids[b])[:6]}")


if __name__ == "__main__":
    main()

"""LM example: train a small llama-family model with the framework substrate.

    PYTHONPATH=src python examples/lm_train.py

Uses the same transformer/optimizer/checkpoint stack the assigned LM
architectures run on, at toy scale: WSD schedule (MiniCPM's), AdamW,
checkpoint+resume, and greedy decoding from the trained model via the
chunked-prefill + decode serving path.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.lm_archs import small_lm
from repro.models import transformer as tf
from repro.optim import schedules
from repro.optim.adamw import AdamW


def make_data(cfg, n=256, S=64, seed=0):
    """Synthetic 'language': arithmetic-progression sequences (learnable)."""
    rng = np.random.default_rng(seed)
    start = rng.integers(0, cfg.vocab_size, n)
    step = rng.integers(1, 7, n)
    toks = (start[:, None] + step[:, None] * np.arange(S)) % cfg.vocab_size
    return jnp.array(toks, jnp.int32)


def main():
    cfg = small_lm()
    params = tf.init_params(cfg, jax.random.key(0))
    n_params = cfg.n_params
    print(f"model: {cfg.n_layers}L d={cfg.d_model} → {n_params:,} params")

    opt = AdamW(lr=functools.partial(schedules.wsd, peak_lr=3e-3,
                                     warmup_steps=20, stable_steps=150,
                                     decay_steps=50))
    ost = opt.init(params)
    data = make_data(cfg)
    mgr = CheckpointManager("/tmp/lm_ckpt", keep=2)

    @jax.jit
    def step(params, ost, batch):
        toks, labels = batch[:, :-1], batch[:, 1:]
        loss, grads = jax.value_and_grad(
            lambda p: tf.lm_loss(cfg, p, toks, labels))(params)
        params, ost = opt.update(grads, ost, params)
        return params, ost, loss

    for it in range(200):
        batch = data[(it * 16) % 240:(it * 16) % 240 + 16]
        params, ost, loss = step(params, ost, batch)
        if (it + 1) % 40 == 0:
            print(f"step {it+1:4d}  loss {float(loss):.4f}  "
                  f"lr {float(schedules.wsd(it+1, 3e-3, 20, 150, 50)):.2e}")
            mgr.save(it + 1, {"params": params})

    # greedy decode with the serving path: the model should continue the
    # arithmetic progression (a training sequence — memorization at toy scale)
    prompt = data[100:101, :16]
    cache = tf.init_kv_cache(cfg, 1, 64, dtype=jnp.float32)
    nxt, logits, cache = tf.serve_step(cfg, params, prompt, cache, jnp.int32(0))
    decoded = [int(nxt[0, 0])]
    pos = 16
    for _ in range(8):
        nxt, _, cache = tf.serve_step(cfg, params, nxt, cache, jnp.int32(pos))
        decoded.append(int(nxt[0, 0]))
        pos += 1
    truth = [int(x) for x in data[100, 16:16 + 9]]
    hits = sum(a == b for a, b in zip(decoded, truth))
    print(f"prompt continuation: {decoded}")
    print(f"ground truth:        {truth}   ({hits}/9 correct)")


if __name__ == "__main__":
    main()

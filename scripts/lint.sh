#!/usr/bin/env bash
# Lint entry point: ruff + mypy (when installed) + the repo's own AST lint.
#
#   scripts/lint.sh          # everything available
#   scripts/lint.sh ruff     # ruff only
#   scripts/lint.sh mypy     # mypy only (strict surface: repro.dist + config)
#   scripts/lint.sh repo     # repro.analysis.repolint only (no deps needed)
#
# ruff/mypy are CI-runner tools (see .github/workflows/ci.yml); the training
# containers intentionally ship without them, so each external tool is gated
# on availability and skipped with a notice instead of failing. The `repo`
# pass is pure stdlib+repo and always runs — it enforces the invariants
# (kernel oracles, frozen configs, confined backend probes) that the other
# tools cannot express.
set -euo pipefail
cd "$(dirname "$0")/.."

want="${1:-all}"
rc=0

run_ruff() {
    if command -v ruff >/dev/null 2>&1; then
        echo "[lint] ruff check src tests"
        ruff check src tests || rc=1
    else
        echo "[lint] ruff not installed — skipped (CI runs it; config in pyproject.toml)"
    fi
}

run_mypy() {
    if command -v mypy >/dev/null 2>&1; then
        echo "[lint] mypy --strict (repro.dist + repro.training.config)"
        mypy src/repro/dist src/repro/training/config.py || rc=1
    else
        echo "[lint] mypy not installed — skipped (CI runs it; config in pyproject.toml)"
    fi
}

run_repo() {
    echo "[lint] repro.analysis repo lint"
    PYTHONPATH=src python -m repro.analysis.preflight --passes lint || rc=1
}

case "$want" in
    ruff) run_ruff;;
    mypy) run_mypy;;
    repo) run_repo;;
    all)  run_ruff; run_mypy; run_repo;;
    *)    echo "usage: scripts/lint.sh [ruff|mypy|repo|all]" >&2; exit 2;;
esac
exit "$rc"

#!/usr/bin/env bash
# Consolidated benchmark entry point: every BENCH_*.json in one command.
#
#   scripts/bench.sh                 # full sweep
#   scripts/bench.sh --quick         # trimmed sweep (BENCH_QUICK=1)
#   scripts/bench.sh --only sampler  # one module
set -euo pipefail
cd "$(dirname "$0")/.."
exec python benchmarks/run.py "$@"

#!/usr/bin/env bash
# Canonical tier-1 entry point (ROADMAP.md): the full suite, fail-fast.
# pyproject.toml sets pythonpath=["src"], so no PYTHONPATH incantation needed.
#
#   scripts/tier1.sh          # full suite
#   scripts/tier1.sh smoke    # fast serving-engine smoke subset (-m serve)
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${1:-}" == "smoke" ]]; then
    shift
    exec python -m pytest -x -q -m serve "$@"
fi
exec python -m pytest -x -q "$@"

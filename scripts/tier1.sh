#!/usr/bin/env bash
# Canonical tier-1 entry point (ROADMAP.md): the full suite, fail-fast.
# pyproject.toml sets pythonpath=["src"], so no PYTHONPATH incantation needed.
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m pytest -x -q "$@"

#!/usr/bin/env bash
# Canonical tier-1 entry point (ROADMAP.md): the full suite, fail-fast.
# pyproject.toml sets pythonpath=["src"], so no PYTHONPATH incantation needed.
#
#   scripts/tier1.sh          # full suite
#   scripts/tier1.sh smoke    # fast serving-engine smoke subset (-m serve)
#   scripts/tier1.sh train    # training-driver smoke subset (-m trainer)
#   scripts/tier1.sh data     # data-layer streaming subset (-m data)
#   scripts/tier1.sh kernels  # Pallas kernel subset, interpret-mode (-m kernels)
#   scripts/tier1.sh shard    # word-sharded model-parallel conformance (-m shard)
#   scripts/tier1.sh preflight # static-analysis launch gate (-m preflight)
#   scripts/tier1.sh concurrency # thread-contract analyzer + interleaving (-m concurrency)
#   scripts/tier1.sh fleet    # multi-replica fleet: routing/shedding/cache (-m fleet)
#   scripts/tier1.sh chaos    # fault-plane injection: breakers/hedges/quarantine (-m chaos)
set -euo pipefail
cd "$(dirname "$0")/.."
case "${1:-}" in
    smoke)
        shift
        exec python -m pytest -x -q -m serve "$@";;
    train)
        shift
        exec python -m pytest -x -q -m trainer "$@";;
    data)
        shift
        exec python -m pytest -x -q -m data "$@";;
    kernels)
        shift
        exec python -m pytest -x -q -m kernels "$@";;
    shard)
        shift
        exec python -m pytest -x -q -m shard "$@";;
    preflight)
        shift
        exec python -m pytest -x -q -m preflight "$@";;
    concurrency)
        shift
        exec python -m pytest -x -q -m concurrency "$@";;
    fleet)
        shift
        exec python -m pytest -x -q -m fleet "$@";;
    chaos)
        shift
        exec python -m pytest -x -q -m chaos "$@";;
esac
exec python -m pytest -x -q "$@"
